//! `feds` — CLI launcher for the FedS reproduction.
//!
//! Subcommands:
//!   info                     runtime + manifest summary
//!   run [opts]               run one experiment from a JSON ExperimentSpec
//!   sweep --spec file [opts] execute a sweep grid from a JSON SweepSpec
//!   serve --spec file [opts] host a cluster run over TCP for client processes
//!   client --spec file [opts] join a hosted cluster run as one client process
//!   train [opts]             legacy flat-flag runner (prefer `run`)
//!   exp <table|all> [opts]   regenerate a paper table/figure
//!   ratio [opts]             Eq. 5 analytic vs measured communication ratio
//!
//! `run`/`sweep` load a spec file and treat explicitly-passed flags as
//! spec overrides (`--sparsity 0.7` → `algo.sparsity`).  Run `feds <cmd>
//! --help` for per-command options.  Usage errors exit with code 2 and the
//! relevant `--help` text; runtime failures exit with code 1.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::Result;

use feds::comm::bandwidth::BandwidthModel;
use feds::exp::sweep::{grid_report, resume_point, run_sweep, run_sweep_from, SweepSpec};
use feds::exp::{self, Ctx};
use feds::fed::cluster::{run_client, ClientOpts, ClusterServer, ServeOpts};
use feds::fed::{comm_ratio, Backend, ExecMode, RunOutcome};
use feds::kge::Method;
use feds::metrics::observe::{ConsoleObserver, JsonlSink, RunObserver};
use feds::spec::{
    AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, Session, TransportSpec,
};
use feds::util::cli::{Cli, CliError};

/// How a command ends without succeeding.
enum Failure {
    /// `--help`: print to stdout, exit 0.
    Help(String),
    /// unusable arguments: print to stderr, exit 2.
    Usage(String),
    /// the run itself failed: print to stderr, exit 1.
    Run(anyhow::Error),
}

impl From<CliError> for Failure {
    fn from(e: CliError) -> Self {
        match e {
            CliError::Help(s) => Failure::Help(s),
            CliError::Usage(s) => Failure::Usage(s),
        }
    }
}

impl From<anyhow::Error> for Failure {
    fn from(e: anyhow::Error) -> Self {
        Failure::Run(e)
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    let rest = &args[1..];
    let result: Result<(), Failure> = match cmd {
        "info" => cmd_info().map_err(Failure::Run),
        "run" => cmd_run(rest),
        "sweep" => cmd_sweep(rest),
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "train" => cmd_train(rest),
        "exp" => cmd_exp(rest),
        "ratio" => cmd_ratio(rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_usage();
            std::process::exit(2);
        }
    };
    match result {
        Ok(()) => {}
        Err(Failure::Help(text)) => println!("{text}"),
        Err(Failure::Usage(text)) => {
            eprintln!("{text}");
            std::process::exit(2);
        }
        Err(Failure::Run(e)) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn print_usage() {
    eprintln!(
        "feds {} — Communication-Efficient Federated KG Embedding (FedS)\n\n\
         USAGE: feds <command> [options]\n\n\
         COMMANDS:\n\
           info     show PJRT runtime and artifact manifest\n\
           run      run one experiment from a JSON spec (flags override spec fields)\n\
           sweep    execute a sweep grid (base spec × axes) from a JSON spec\n\
           serve    host a cluster run: accept client processes, drive the rounds\n\
           client   join a hosted cluster run as one client process\n\
           train    legacy flat-flag runner (prefer `run`)\n\
           exp      regenerate paper tables/figures: table1 table23 table4\n\
                    table5 table6 fig2 all\n\
           ratio    Eq. 5 analytic communication ratio vs sparsity\n",
        feds::version()
    );
}

fn cmd_info() -> Result<()> {
    let rt = exp::xla_runtime()?;
    let m = &rt.manifest;
    println!("artifacts dir : {}", m.dir.display());
    println!("entities      : {}", m.num_entities);
    println!("relations     : {}", m.num_relations);
    println!("dim           : {} (FedEPL {}, KD {})", m.hyper.dim, m.fedepl_dim, m.kd_dim);
    println!("batch         : {} × {} negatives", m.batch, m.negatives);
    println!("eval batch    : {}", m.eval_batch);
    println!("sparsity p    : {}", m.sparsity);
    println!("sync interval : {}", m.sync_interval);
    println!("artifacts ({}):", m.artifacts.len());
    for a in &m.artifacts {
        println!("  {:<24} {:?} {:<8} dim {}", a.name, a.role, a.method.name(), a.dim);
    }
    Ok(())
}

/// Flag name → dotted spec key, shared by `run` and `sweep`.  Only flags
/// the user explicitly passed are applied, so spec-file values survive.
const OVERRIDE_FLAGS: &[(&str, &str)] = &[
    ("algo", "algo"),
    ("method", "method"),
    ("clients", "data.clients"),
    ("entities", "data.entities"),
    ("relations", "data.relations"),
    ("triples", "data.triples"),
    ("data-seed", "data.seed"),
    ("rounds", "budget.max_rounds"),
    ("local-epochs", "budget.local_epochs"),
    ("eval-every", "budget.eval_every"),
    ("patience", "budget.patience"),
    ("eval-cap", "budget.eval_cap"),
    ("sparsity", "algo.sparsity"),
    ("sync-interval", "algo.sync_interval"),
    ("svd-cols", "algo.cols"),
    ("backend", "backend"),
    ("dim", "backend.dim"),
    ("batch", "backend.batch"),
    ("seed", "seed"),
    ("exec", "exec"),
    ("transport", "transport"),
    ("shards", "shards"),
    ("participation-fraction", "participation.fraction"),
    ("participation-k", "participation.k"),
    ("store", "storage"),
    ("compress", "compression"),
];

fn override_opts(mut cli: Cli) -> Cli {
    cli = cli
        .opt("algo", "feds", "single|fedep|fedepl|feds|feds-nosync|fedkd|fedsvd|fedsvd+")
        .opt("method", "transe", "transe|rotate|complex")
        .opt("clients", "3", "number of clients (relation partition)")
        .opt("entities", "512", "number of KG entities")
        .opt("relations", "24", "number of KG relations")
        .opt("triples", "8000", "number of KG triples")
        .opt("data-seed", "64501", "KG generation/partition seed")
        .opt("rounds", "60", "max communication rounds")
        .opt("local-epochs", "3", "local epochs per round")
        .opt("eval-every", "5", "evaluate every N rounds")
        .opt("patience", "3", "early-stop patience in evaluations")
        .opt("eval-cap", "384", "max eval queries per client per split (0=all)")
        .opt("sparsity", "0.4", "FedS sparsity ratio p (feds only)")
        .opt("sync-interval", "4", "FedS synchronization interval s (feds only)")
        .opt("svd-cols", "8", "SVD reshape columns (fedsvd only)")
        .opt("backend", "native", "xla|native")
        .opt("dim", "32", "native embedding dimension")
        .opt("batch", "128", "native training batch size")
        .opt("seed", "64501", "experiment seed")
        .opt("exec", "seq", "client execution: seq|threaded (threaded is native-only)")
        .opt("transport", "mpsc", "frame transport: mpsc|tcp (loopback sockets)")
        .opt("shards", "0", "server aggregation shards (0 = auto: one per core, capped)")
        .opt("participation-fraction", "1.0", "sample ⌈f·live⌉ clients/round (cluster serve)")
        .opt("participation-k", "0", "sample k clients per round (cluster serve)")
        .opt("store", "ram", "embedding storage backend: ram|mmap|mmap:<dir>")
        .opt(
            "compress",
            "",
            "delta compression stack, e.g. topk,int8:ef (stages topk[@p]|int8|fp16|svd[@c], \
             :ef = error feedback; dense algos only)",
        );
    cli
}

/// The built-in default spec `feds run` executes when no `--spec` is
/// given: FedS on the native backend's standard synthetic KG.
fn default_spec() -> ExperimentSpec {
    ExperimentSpec {
        name: "run".into(),
        method: Method::TransE,
        algo: AlgoSpec::feds(),
        data: DataSpec {
            entities: 512,
            relations: 24,
            triples: 8_000,
            clusters: 8,
            clients: 3,
            seed: 64501,
        },
        backend: BackendSpec::native_default(),
        budget: BudgetSpec {
            max_rounds: 60,
            local_epochs: 3,
            eval_every: 5,
            patience: 3,
            eval_cap: 384,
        },
        seed: 64501,
        exec: ExecMode::Sequential,
        transport: TransportSpec::Mpsc,
        shards: 0,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    }
}

fn apply_overrides(
    spec: &mut ExperimentSpec,
    m: &feds::util::cli::Matches,
) -> Result<(), Failure> {
    for (flag, key) in OVERRIDE_FLAGS {
        if let Some(raw) = m.explicit(flag) {
            let raw = raw.to_string();
            spec.apply_str(key, &raw)
                .map_err(|e| Failure::Usage(format!("{e:#}")))?;
        }
    }
    spec.validate().map_err(|e| Failure::Usage(format!("{e:#}")))?;
    Ok(())
}

fn print_outcome(out: &RunOutcome) {
    println!("\n=== {} ===", out.history.label);
    println!("{:>6} {:>12} {:>10} {:>10} {:>10}", "round", "params", "loss", "validMRR", "testMRR");
    for r in &out.history.records {
        println!(
            "{:>6} {:>12} {:>10.4} {:>10.4} {:>10.4}",
            r.round, r.params_cum, r.mean_loss, r.valid.mrr, r.test.mrr
        );
    }
    if out.history.records.is_empty() {
        println!("\nno evaluations recorded (eval-every exceeds the round budget)");
    } else {
        println!(
            "\nconverged: round {} MRR {:.4} Hits@10 {:.4}",
            out.history.rounds_cg(),
            out.history.mrr_cg(),
            out.history.hits10_cg()
        );
    }
    println!(
        "transmitted: {} params, {} bytes ({} messages)",
        out.acct.params(),
        out.acct.bytes(),
        out.acct.messages()
    );
    if let Some(r) = out.eq5_ratio {
        println!("Eq.5 worst-case ratio vs dense: {r:.4}");
    }
}

fn run_cli() -> Cli {
    override_opts(Cli::new(
        "feds run",
        "run one experiment from a JSON ExperimentSpec (explicit flags override spec fields)",
    ))
    .opt("spec", "", "path to an ExperimentSpec JSON file (empty = built-in default)")
    .opt("jsonl", "", "stream run events to this JSONL file")
    .flag("quiet", "suppress console progress")
}

fn cmd_run(args: &[String]) -> Result<(), Failure> {
    let cli = run_cli();
    let m = cli.parse(args)?;
    if let Some(stray) = m.positional.first() {
        return Err(Failure::Usage(format!(
            "unexpected argument '{stray}' — spec files are passed as --spec {stray}\n\n{}",
            cli.usage()
        )));
    }
    let spec_path = m.get("spec").map_err(Failure::Usage)?;
    let mut spec = if spec_path.is_empty() {
        default_spec()
    } else {
        ExperimentSpec::load(Path::new(spec_path))?
    };
    apply_overrides(&mut spec, &m)?;

    let mut session = Session::new();
    let mut run = session.build(&spec)?;
    if m.flag("quiet") {
        run.quiet();
    }
    let jsonl = m.get("jsonl").map_err(Failure::Usage)?;
    if !jsonl.is_empty() {
        run.observe(Box::new(JsonlSink::create(Path::new(jsonl))?));
    }
    let out = run.execute()?;
    print_outcome(&out);
    Ok(())
}

fn sweep_cli() -> Cli {
    override_opts(Cli::new(
        "feds sweep",
        "execute a sweep grid (base ExperimentSpec × axes); flags override the base spec",
    ))
    .opt("spec", "", "path to a SweepSpec JSON file (required)")
    .opt("jsonl", "", "stream all runs' events to this JSONL file")
    .flag(
        "resume",
        "skip cells whose runs already completed in the --jsonl stream (counted by \
         run_end events) and append the remaining cells to it",
    )
}

fn cmd_sweep(args: &[String]) -> Result<(), Failure> {
    let cli = sweep_cli();
    let m = cli.parse(args)?;
    if let Some(stray) = m.positional.first() {
        return Err(Failure::Usage(format!(
            "unexpected argument '{stray}' — spec files are passed as --spec {stray}\n\n{}",
            cli.usage()
        )));
    }
    let spec_path = m.get("spec").map_err(Failure::Usage)?;
    if spec_path.is_empty() {
        return Err(Failure::Usage(format!("--spec is required\n\n{}", cli.usage())));
    }
    let mut sweep = SweepSpec::load(Path::new(spec_path))?;
    apply_overrides(&mut sweep.base, &m)?;

    let mut session = Session::new();
    let jsonl = m.get("jsonl").map_err(Failure::Usage)?;
    let resume = m.flag("resume");
    if resume && jsonl.is_empty() {
        return Err(Failure::Usage(format!(
            "--resume needs the sweep's --jsonl stream to know which cells completed\n\n{}",
            cli.usage()
        )));
    }
    let grid = if jsonl.is_empty() {
        run_sweep(&mut session, &sweep, &mut [])?
    } else if resume {
        let path = Path::new(jsonl);
        let skip = resume_point(&sweep, path)?;
        let mut sink = JsonlSink::append(path)?;
        run_sweep_from(&mut session, &sweep, skip, &mut [&mut sink])?
    } else {
        let mut sink = JsonlSink::create(Path::new(jsonl))?;
        run_sweep(&mut session, &sweep, &mut [&mut sink])?
    };
    if grid.cells.is_empty() {
        // a fully-resumed sweep: nothing ran, so don't overwrite the
        // saved report with an empty table
        println!(
            "sweep '{}' already complete ({} cells recorded in {jsonl}); nothing to run",
            grid.name, grid.start
        );
        return Ok(());
    }
    let rep = grid_report(&grid);
    rep.save(&exp::reports_dir())?;
    Ok(())
}

/// `--rate-mbps`/`--latency-ms` → the per-link rate model shared by
/// `serve` and `client` (`None` = unthrottled loopback).  Both values
/// are validated up front: a NaN, infinite, or negative rate/latency is
/// a usage error, never a silently-unthrottled link.
fn bandwidth_model(m: &feds::util::cli::Matches) -> Result<Option<BandwidthModel>, Failure> {
    let mbps = m.f64("rate-mbps").map_err(Failure::Usage)?;
    let latency_ms = m.f64("latency-ms").map_err(Failure::Usage)?;
    if !mbps.is_finite() || mbps < 0.0 {
        return Err(Failure::Usage(format!(
            "--rate-mbps must be a finite rate >= 0 (0 = unthrottled), got {mbps}"
        )));
    }
    if !latency_ms.is_finite() || latency_ms < 0.0 {
        return Err(Failure::Usage(format!(
            "--latency-ms must be a finite delay >= 0, got {latency_ms}"
        )));
    }
    if mbps == 0.0 {
        return Ok(None);
    }
    Ok(Some(BandwidthModel { bytes_per_sec: mbps * 1e6 / 8.0, latency_s: latency_ms / 1e3 }))
}

fn serve_cli() -> Cli {
    Cli::new("feds serve", "host a cluster run: accept client processes and drive the rounds")
        .opt("spec", "", "path to an ExperimentSpec JSON file (required; native backend)")
        .opt("bind", "127.0.0.1:7464", "listen address HOST:PORT (port 0 = ephemeral)")
        .opt("deadline-ms", "30000", "per-round report deadline before partial aggregation")
        .opt("expect", "0", "clients required before round 1 starts (0 = every client)")
        .opt("rate-mbps", "0", "rate-limit every link to this many Mbit/s (0 = unthrottled)")
        .opt("latency-ms", "0", "per-message link latency for the rate model")
        .opt("checkpoint", "", "write round-boundary checkpoints into this directory")
        .opt("checkpoint-every", "1", "rounds between checkpoints (requires --checkpoint)")
        .opt("restore", "", "resume from the checkpoint in this directory")
        .opt("chaos-halt-at", "0", "fault drill: halt after this round's checkpoint (0 = never)")
        .opt("chaos-kill-at", "0", "fault drill: SIGKILL after this round's checkpoint (0 = never)")
        .opt("jsonl", "", "stream run events to this JSONL file (appended when restoring)")
        .flag("quiet", "suppress console progress")
}

fn cmd_serve(args: &[String]) -> Result<(), Failure> {
    let cli = serve_cli();
    let m = cli.parse(args)?;
    let spec_path = m.get("spec").map_err(Failure::Usage)?;
    if spec_path.is_empty() {
        return Err(Failure::Usage(format!("--spec is required\n\n{}", cli.usage())));
    }
    let spec = ExperimentSpec::load(Path::new(spec_path))?;
    let deadline_ms = m.u64("deadline-ms").map_err(Failure::Usage)?;
    if deadline_ms == 0 {
        return Err(Failure::Usage("--deadline-ms must be a positive duration".into()));
    }
    let every = m.u64("checkpoint-every").map_err(Failure::Usage)? as u32;
    if every == 0 {
        return Err(Failure::Usage("--checkpoint-every must be >= 1".into()));
    }
    let ckpt_dir = m.get("checkpoint").map_err(Failure::Usage)?;
    let restore_dir = m.get("restore").map_err(Failure::Usage)?;
    let halt = m.u64("chaos-halt-at").map_err(Failure::Usage)? as u32;
    let kill = m.u64("chaos-kill-at").map_err(Failure::Usage)? as u32;
    if (halt > 0 || kill > 0) && ckpt_dir.is_empty() {
        let why = "--chaos-halt-at/--chaos-kill-at require --checkpoint (the drill crashes \
                   at a checkpoint boundary)";
        return Err(Failure::Usage(why.into()));
    }
    let restoring = !restore_dir.is_empty();
    let opts = ServeOpts {
        deadline: Duration::from_millis(deadline_ms),
        bandwidth: bandwidth_model(&m)?,
        expect: m.usize("expect").map_err(Failure::Usage)?,
        checkpoint: (!ckpt_dir.is_empty()).then(|| PathBuf::from(ckpt_dir)),
        checkpoint_every: every,
        restore: restoring.then(|| PathBuf::from(restore_dir)),
        halt_after_checkpoint: (halt > 0).then_some(halt),
        kill_after_checkpoint: (kill > 0).then_some(kill),
    };
    let server = ClusterServer::bind(m.get("bind").map_err(Failure::Usage)?, &spec, opts)?;
    // harnesses parse this line to learn an ephemeral port; flush
    // explicitly, since a piped stdout is block-buffered
    println!("listening on {}", server.addr());
    std::io::stdout().flush().map_err(|e| Failure::Run(e.into()))?;

    let mut console = ConsoleObserver::new();
    let mut sink = None;
    let jsonl = m.get("jsonl").map_err(Failure::Usage)?;
    if !jsonl.is_empty() {
        // a restored run continues the interrupted run's event stream in
        // place, so the final file reads as one contiguous history
        sink = Some(if restoring {
            JsonlSink::append(Path::new(jsonl))?
        } else {
            JsonlSink::create(Path::new(jsonl))?
        });
    }
    let mut observers: Vec<&mut dyn RunObserver> = Vec::new();
    if !m.flag("quiet") {
        observers.push(&mut console);
    }
    if let Some(s) = sink.as_mut() {
        observers.push(s);
    }
    let out = server.run(&mut observers)?;
    print_outcome(&out.run);
    let t = &out.times;
    println!(
        "wall-clock: {} rounds, mean {:.3}s, max {:.3}s, total {:.1}s",
        t.secs.len(),
        t.mean(),
        t.max(),
        t.total()
    );
    Ok(())
}

fn client_cli() -> Cli {
    Cli::new("feds client", "join a cluster run hosted by `feds serve` as one client process")
        .opt("spec", "", "path to the server's ExperimentSpec JSON file (required)")
        .opt("connect", "127.0.0.1:7464", "server address HOST:PORT")
        .opt("id", "0", "this client's id within the spec's fleet")
        .opt("join-at", "0", "defer participation until this round (0 = join immediately)")
        .opt("rate-mbps", "0", "rate-limit the uplink to this many Mbit/s (0 = unthrottled)")
        .opt("latency-ms", "0", "per-message link latency for the rate model")
        .opt("reconnect-attempts", "8", "re-dials per lost connection before giving up")
        .opt("leave-after", "0", "failure drill: leave cleanly after this round (0 = never)")
        .opt("fail-after", "0", "failure drill: crash mid-frame after this round (0 = never)")
}

fn cmd_client(args: &[String]) -> Result<(), Failure> {
    let cli = client_cli();
    let m = cli.parse(args)?;
    let spec_path = m.get("spec").map_err(Failure::Usage)?;
    if spec_path.is_empty() {
        return Err(Failure::Usage(format!("--spec is required\n\n{}", cli.usage())));
    }
    let spec = ExperimentSpec::load(Path::new(spec_path))?;
    let id = m.usize("id").map_err(Failure::Usage)? as u16;
    let mut opts = ClientOpts::new(m.get("connect").map_err(Failure::Usage)?, id);
    opts.join_round = m.usize("join-at").map_err(Failure::Usage)? as u32;
    opts.bandwidth = bandwidth_model(&m)?;
    opts.reconnect.attempts = m.u64("reconnect-attempts").map_err(Failure::Usage)? as u32;
    let leave = m.usize("leave-after").map_err(Failure::Usage)?;
    opts.leave_after = (leave > 0).then_some(leave);
    let fail = m.usize("fail-after").map_err(Failure::Usage)?;
    opts.fail_after = (fail > 0).then_some(fail);
    run_client(&spec, &opts)?;
    println!("client {id} done");
    Ok(())
}

fn train_cli() -> Cli {
    Cli::new("feds train", "legacy flat-flag runner (prefer `feds run`)")
        .opt("algo", "feds", "single|fedep|fedepl|feds|feds-nosync|fedkd|fedsvd|fedsvd+")
        .opt("method", "transe", "transe|rotate|complex")
        .opt("clients", "3", "number of clients (relation partition)")
        .opt("rounds", "60", "max communication rounds")
        .opt("local-epochs", "3", "local epochs per round")
        .opt("eval-every", "5", "evaluate every N rounds")
        .opt("sparsity", "0.4", "FedS sparsity ratio p")
        .opt("sync-interval", "4", "FedS synchronization interval s")
        .opt("eval-cap", "384", "max eval queries per client per split (0=all)")
        .opt("seed", "64501", "experiment seed")
        .opt("backend", "xla", "xla|native")
        .opt("exec", "seq", "client execution: seq|threaded (threaded is native-only)")
        .opt("triples", "0", "override #triples (0 = backend default)")
}

fn cmd_train(args: &[String]) -> Result<(), Failure> {
    let m = train_cli().parse(args)?;
    let seed = m.u64("seed").map_err(Failure::Usage)?;
    let ctx = Ctx::from_options(m.get("backend").map_err(Failure::Usage)?, false, seed)?;
    let gen = ctx.gen_config();
    let triples = m.usize("triples").map_err(Failure::Usage)?;
    let mut algo = AlgoSpec::parse(m.get("algo").map_err(Failure::Usage)?)?;
    if let AlgoSpec::FedS { sparsity, sync_interval, .. } = &mut algo {
        *sparsity = m.f64("sparsity").map_err(Failure::Usage)?;
        *sync_interval = m.usize("sync-interval").map_err(Failure::Usage)?;
    }
    let spec = ExperimentSpec {
        name: "train".into(),
        method: Method::parse(m.get("method").map_err(Failure::Usage)?)?,
        algo,
        data: DataSpec {
            entities: gen.num_entities,
            relations: gen.num_relations,
            triples: if triples > 0 { triples } else { gen.num_triples },
            clusters: gen.num_clusters,
            clients: m.usize("clients").map_err(Failure::Usage)?,
            seed,
        },
        backend: ctx.backend_spec(),
        budget: BudgetSpec {
            max_rounds: m.usize("rounds").map_err(Failure::Usage)?,
            local_epochs: m.usize("local-epochs").map_err(Failure::Usage)?,
            eval_every: m.usize("eval-every").map_err(Failure::Usage)?,
            patience: 3,
            eval_cap: m.usize("eval-cap").map_err(Failure::Usage)?,
        },
        seed,
        exec: ExecMode::parse(m.get("exec").map_err(Failure::Usage)?)?,
        transport: TransportSpec::Mpsc,
        shards: 0,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    };
    let mut session = match &ctx.backend {
        Backend::Xla(rt) => Session::with_runtime(rt.clone()),
        _ => Session::new(),
    };
    let out = session.build(&spec)?.execute()?;
    print_outcome(&out);
    Ok(())
}

fn exp_cli() -> Cli {
    Cli::new("feds exp", "regenerate a paper table/figure")
        .opt("backend", "xla", "xla|native")
        .opt("seed", "64501", "experiment seed")
        .opt("exec", "seq", "client execution: seq|threaded (threaded is native-only)")
        .flag("fast", "CI smoke mode: fewer rounds, smaller eval cap")
}

fn cmd_exp(args: &[String]) -> Result<(), Failure> {
    // parse first, then read the experiment name from the positionals —
    // so `feds exp --fast` selects "all" instead of treating "--fast" as
    // the experiment name
    let cli = exp_cli();
    let m = cli.parse(args)?;
    let which = m.positional.first().cloned().unwrap_or_else(|| "all".to_string());
    if m.positional.len() > 1 {
        return Err(Failure::Usage(format!(
            "unexpected extra argument '{}'\n\n{}",
            m.positional[1],
            cli.usage()
        )));
    }
    let ctx = Ctx::from_options(
        m.get("backend").map_err(Failure::Usage)?,
        m.flag("fast"),
        m.u64("seed").map_err(Failure::Usage)?,
    )?
    .with_exec(ExecMode::parse(m.get("exec").map_err(Failure::Usage)?)?);
    let dir = exp::reports_dir();
    let run_one = |name: &str| -> Result<()> {
        let rep = match name {
            "table1" => exp::table1::run(&ctx)?,
            "table23" => exp::table23::run(&ctx)?,
            "table4" => exp::table4::run(&ctx)?,
            "table5" => exp::table5::run(&ctx)?,
            "table6" => exp::table6::run(&ctx)?,
            "fig2" => exp::fig2::run(&ctx)?,
            other => anyhow::bail!("unknown experiment '{other}'"),
        };
        rep.save(&dir)
    };
    if which == "all" {
        for name in ["table23", "table1", "table4", "fig2", "table5", "table6"] {
            println!("\n################ {name} ################\n");
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(&which).map_err(Failure::Run)
    }
}

fn cmd_ratio(args: &[String]) -> Result<(), Failure> {
    let cli = Cli::new("feds ratio", "Eq. 5 analytic communication ratio")
        .opt("dim", "64", "embedding width D")
        .opt("sync-interval", "4", "synchronization interval s");
    let m = cli.parse(args)?;
    let d = m.usize("dim").map_err(Failure::Usage)?;
    let s = m.usize("sync-interval").map_err(Failure::Usage)?;
    println!("Eq. 5 ratio R_c^p for D={d}, s={s}:");
    for p in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9] {
        println!("  p={p:.1} → {:.4}", comm_ratio(p, s, d));
    }
    Ok(())
}
