//! `feds` — CLI launcher for the FedS reproduction.
//!
//! Subcommands:
//!   info                     runtime + manifest summary
//!   train [opts]             run one federated training configuration
//!   exp <table|all> [opts]   regenerate a paper table/figure
//!   ratio [opts]             Eq. 5 analytic vs measured communication ratio
//!
//! Run `feds <cmd> --help` for per-command options.

use anyhow::Result;

use feds::data::generator::generate;
use feds::data::partition::partition;
use feds::exp::{self, Ctx};
use feds::fed::{comm_ratio, run_federated, Algo, ExecMode, FedRunConfig};
use feds::kge::Method;
use feds::util::cli::Cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    let rest = &args[1..];
    let result = match cmd {
        "info" => cmd_info(),
        "train" => cmd_train(rest),
        "exp" => cmd_exp(rest),
        "ratio" => cmd_ratio(rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "feds {} — Communication-Efficient Federated KG Embedding (FedS)\n\n\
         USAGE: feds <command> [options]\n\n\
         COMMANDS:\n\
           info     show PJRT runtime and artifact manifest\n\
           train    run one federated configuration and print the history\n\
           exp      regenerate paper tables/figures: table1 table23 table4\n\
                    table5 table6 fig2 all\n\
           ratio    Eq. 5 analytic communication ratio vs sparsity\n",
        feds::version()
    );
}

fn cmd_info() -> Result<()> {
    let rt = exp::xla_runtime()?;
    let m = &rt.manifest;
    println!("artifacts dir : {}", m.dir.display());
    println!("entities      : {}", m.num_entities);
    println!("relations     : {}", m.num_relations);
    println!("dim           : {} (FedEPL {}, KD {})", m.hyper.dim, m.fedepl_dim, m.kd_dim);
    println!("batch         : {} × {} negatives", m.batch, m.negatives);
    println!("eval batch    : {}", m.eval_batch);
    println!("sparsity p    : {}", m.sparsity);
    println!("sync interval : {}", m.sync_interval);
    println!("artifacts ({}):", m.artifacts.len());
    for a in &m.artifacts {
        println!("  {:<24} {:?} {:<8} dim {}", a.name, a.role, a.method.name(), a.dim);
    }
    Ok(())
}

fn train_cli() -> Cli {
    Cli::new("feds train", "run one federated training configuration")
        .opt("algo", "feds", "single|fedep|fedepl|feds|feds-nosync|fedkd|fedsvd|fedsvd+")
        .opt("method", "transe", "transe|rotate|complex")
        .opt("clients", "3", "number of clients (relation partition)")
        .opt("rounds", "60", "max communication rounds")
        .opt("local-epochs", "3", "local epochs per round")
        .opt("eval-every", "5", "evaluate every N rounds")
        .opt("sparsity", "0.4", "FedS sparsity ratio p")
        .opt("sync-interval", "4", "FedS synchronization interval s")
        .opt("eval-cap", "384", "max eval queries per client per split (0=all)")
        .opt("seed", "64501", "experiment seed")
        .opt("backend", "xla", "xla|native")
        .opt("exec", "seq", "client execution: seq|threaded (threaded is native-only)")
        .opt("triples", "0", "override #triples (0 = backend default)")
}

fn cmd_train(args: &[String]) -> Result<()> {
    let m = train_cli().parse(args).map_err(|u| anyhow::anyhow!("{u}"))?;
    let ctx = Ctx::from_options(m.get("backend"), false, m.u64("seed"))?;
    let mut gen = ctx.gen_config();
    if m.usize("triples") > 0 {
        gen.num_triples = m.usize("triples");
    }
    let kg = generate(&gen);
    let data = partition(&kg, m.usize("clients"), m.u64("seed"));
    let cfg = FedRunConfig {
        algo: Algo::parse(m.get("algo"))?,
        method: Method::parse(m.get("method"))?,
        max_rounds: m.usize("rounds"),
        local_epochs: m.usize("local-epochs"),
        eval_every: m.usize("eval-every"),
        patience: 3,
        sparsity: m.f64("sparsity"),
        sync_interval: m.usize("sync-interval"),
        eval_cap: m.usize("eval-cap"),
        seed: m.u64("seed"),
        svd_cols: 8,
        exec: ExecMode::parse(m.get("exec"))?,
    };
    let out = run_federated(&data, &cfg, &ctx.backend)?;
    println!("\n=== {} ===", out.history.label);
    println!("{:>6} {:>12} {:>10} {:>10} {:>10}", "round", "params", "loss", "validMRR", "testMRR");
    for r in &out.history.records {
        println!(
            "{:>6} {:>12} {:>10.4} {:>10.4} {:>10.4}",
            r.round, r.params_cum, r.mean_loss, r.valid.mrr, r.test.mrr
        );
    }
    println!(
        "\nconverged: round {} MRR {:.4} Hits@10 {:.4}",
        out.history.rounds_cg(),
        out.history.mrr_cg(),
        out.history.hits10_cg()
    );
    println!(
        "transmitted: {} params, {} bytes ({} messages)",
        out.acct.params(),
        out.acct.bytes(),
        out.acct.messages()
    );
    if let Some(r) = out.eq5_ratio {
        println!("Eq.5 worst-case ratio vs dense: {r:.4}");
    }
    Ok(())
}

fn exp_cli() -> Cli {
    Cli::new("feds exp", "regenerate a paper table/figure")
        .opt("backend", "xla", "xla|native")
        .opt("seed", "64501", "experiment seed")
        .opt("exec", "seq", "client execution: seq|threaded (threaded is native-only)")
        .flag("fast", "CI smoke mode: fewer rounds, smaller eval cap")
}

fn cmd_exp(args: &[String]) -> Result<()> {
    let which = args.first().cloned().unwrap_or_else(|| "all".to_string());
    let m = exp_cli()
        .parse(&args[1.min(args.len())..])
        .map_err(|u| anyhow::anyhow!("{u}"))?;
    let ctx = Ctx::from_options(m.get("backend"), m.flag("fast"), m.u64("seed"))?
        .with_exec(ExecMode::parse(m.get("exec"))?);
    let dir = exp::reports_dir();
    let run_one = |name: &str| -> Result<()> {
        let rep = match name {
            "table1" => exp::table1::run(&ctx)?,
            "table23" => exp::table23::run(&ctx)?,
            "table4" => exp::table4::run(&ctx)?,
            "table5" => exp::table5::run(&ctx)?,
            "table6" => exp::table6::run(&ctx)?,
            "fig2" => exp::fig2::run(&ctx)?,
            other => anyhow::bail!("unknown experiment '{other}'"),
        };
        rep.save(&dir)
    };
    if which == "all" {
        for name in ["table23", "table1", "table4", "fig2", "table5", "table6"] {
            println!("\n################ {name} ################\n");
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(&which)
    }
}

fn cmd_ratio(args: &[String]) -> Result<()> {
    let cli = Cli::new("feds ratio", "Eq. 5 analytic communication ratio")
        .opt("dim", "64", "embedding width D")
        .opt("sync-interval", "4", "synchronization interval s");
    let m = cli.parse(args).map_err(|u| anyhow::anyhow!("{u}"))?;
    let d = m.usize("dim");
    let s = m.usize("sync-interval");
    println!("Eq. 5 ratio R_c^p for D={d}, s={s}:");
    for p in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9] {
        println!("  p={p:.1} → {:.4}", comm_ratio(p, s, d));
    }
    Ok(())
}
