//! Property-test helper (proptest is unavailable offline; DESIGN.md §5).
//!
//! `check(name, cases, |rng| ...)` runs the closure over `cases` random
//! seeds; on failure it panics with the failing seed so the case can be
//! replayed with `FEDS_PROP_SEED=<seed>`.  Setting `FEDS_PROP_CASES`
//! scales iteration counts globally.

use super::rng::Rng;

pub fn cases(default: usize) -> usize {
    std::env::var("FEDS_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Run `f` for `n` random cases. `f` should panic (assert) on failure.
pub fn check<F: FnMut(&mut Rng)>(name: &str, n: usize, mut f: F) {
    if let Ok(s) = std::env::var("FEDS_PROP_SEED") {
        let seed: u64 = s.parse().expect("FEDS_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        f(&mut rng);
        return;
    }
    let base = 0xFED5_0000_0000_0000u64 ^ fnv(name);
    for i in 0..cases(n) {
        let seed = base.wrapping_add(i as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {i} (replay with FEDS_PROP_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_for_true_property() {
        check("sum_commutes", 20, |rng| {
            let a = rng.f64();
            let b = rng.f64();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic]
    fn check_fails_for_false_property() {
        check("always_false", 20, |rng| {
            assert!(rng.f64() < 0.0);
        });
    }

    #[test]
    fn fnv_distinguishes_names() {
        assert_ne!(fnv("a"), fnv("b"));
    }
}
