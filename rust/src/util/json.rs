//! Minimal JSON parser/writer (serde is unavailable offline; DESIGN.md §5).
//!
//! Covers the full JSON grammar we produce and consume: the artifact
//! manifest written by `python/compile/aot.py` and the experiment reports.
//! Object key order is preserved (insertion order) so reports diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // --- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn obj_entries(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    // --- builders ----------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kvs) = self {
            kvs.push((key.to_string(), v.into()));
        }
        self
    }

    // --- writer -------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((d + 1) * 2));
                        v.write(out, Some(d + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let (Some(d), false) = (indent, a.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(d * 2));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((d + 1) * 2));
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent.map(|d| d + 1));
                }
                if let (Some(d), false) = (indent, o.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(d * 2));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(m: BTreeMap<String, Json>) -> Json {
        Json::Obj(m.into_iter().collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name": "feds", "nums": [1, 2.5, -3], "ok": true, "nest": {"x": []}}"#;
        let v = Json::parse(src).unwrap();
        let rt = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj()
            .set("a", 1usize)
            .set("b", vec!["x", "y"])
            .set("c", Json::obj().set("d", false));
        let rt = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() >= 3);
        }
    }
}
