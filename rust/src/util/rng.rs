//! Deterministic PRNG for the whole stack (no `rand` offline).
//!
//! SplitMix64 seeds Xoshiro256**; every subsystem takes an explicit seed so
//! experiment runs are bit-reproducible end-to-end (the paper's tables are
//! regenerated from fixed seeds recorded in EXPERIMENTS.md).

/// SplitMix64 — used to expand a single u64 seed into a full state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream (for per-client / per-module seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the generator state (for checkpointing a stream position).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact stream position saved by [`state`].
    ///
    /// [`state`]: Rng::state
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    pub fn u32_below(&mut self, n: u32) -> u32 {
        self.below(n as u64) as u32
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    /// Zipf-like rank sampler over `[0, n)` with exponent `a` (a=0 → uniform).
    /// Uses inverse-CDF on the continuous approximation — fine for data
    /// generation purposes.
    pub fn zipf(&mut self, n: usize, a: f64) -> usize {
        if a <= 1e-9 {
            return self.usize_below(n);
        }
        // Continuous inverse-CDF over [0, n) so every rank (including n-1)
        // has positive mass after flooring.
        let u = self.f64();
        if (a - 1.0).abs() < 1e-9 {
            let h = (n as f64 + 1.0).ln();
            return (((u * h).exp() - 1.0) as usize).min(n - 1);
        }
        let p = 1.0 - a;
        let h = ((n as f64 + 1.0).powf(p) - 1.0) / p;
        let x = (1.0 + u * h * p).powf(1.0 / p) - 1.0;
        (x as usize).min(n - 1)
    }
}

#[inline]
fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let w = (a as u128) * (b as u128);
    ((w >> 64) as u64, w as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(11);
        let m: f64 = (0..20_000).map(|_| r.uniform(-1.0, 1.0) as f64).sum::<f64>() / 20_000.0;
        assert!(m.abs() < 0.02, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zipf_skewed_toward_low_ranks() {
        let mut r = Rng::new(13);
        let mut lo = 0usize;
        let n = 1000;
        for _ in 0..10_000 {
            let x = r.zipf(n, 1.0);
            assert!(x < n);
            if x < 100 {
                lo += 1;
            }
        }
        // top 10% of ranks should get far more than 10% of mass
        assert!(lo > 3_000, "low-rank mass {lo}");
    }

    #[test]
    fn zipf_uniform_when_a_zero() {
        let mut r = Rng::new(17);
        let mut lo = 0usize;
        for _ in 0..10_000 {
            if r.zipf(1000, 0.0) < 100 {
                lo += 1;
            }
        }
        assert!((800..1200).contains(&lo), "{lo}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
