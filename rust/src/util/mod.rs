//! Shared infrastructure: deterministic RNG, JSON, CLI parsing, logging,
//! micro-bench harness and property-test helper.  All hand-rolled because the
//! offline registry lacks rand/serde/clap/criterion/proptest (DESIGN.md §5).

pub mod bench;
pub mod cli;
pub mod fsio;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
