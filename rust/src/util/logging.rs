//! Leveled stderr logger controlled by `FEDS_LOG` (error|warn|info|debug).
//! Default level is `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let parsed = match std::env::var("FEDS_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if (l as u8) <= level() {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {}] {args}", tag(l));
    }
}

fn tag(l: Level) -> &'static str {
    match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_ordered() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_level_silences() {
        set_level(Level::Error);
        log(Level::Debug, format_args!("should not print"));
        set_level(Level::Info);
    }
}
