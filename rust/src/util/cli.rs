//! Tiny declarative CLI parser (clap is unavailable offline; DESIGN.md §5).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional args
//! and auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Default)]
pub struct Cli {
    pub name: String,
    pub about: String,
    specs: Vec<ArgSpec>,
}

#[derive(Debug, Clone)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(name: &str, about: &str) -> Self {
        Self { name: name.into(), about: about.into(), specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for spec in &self.specs {
            let tail = if spec.is_flag {
                String::new()
            } else {
                format!(" <val>  (default: {})", spec.default.as_deref().unwrap_or(""))
            };
            s.push_str(&format!("  --{}{}\n      {}\n", spec.name, tail, spec.help));
        }
        s
    }

    pub fn parse(&self, args: &[String]) -> Result<Matches, String> {
        let mut m = Matches {
            values: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
        };
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                m.values.insert(spec.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    m.flags.push(key);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    m.values.insert(key, v);
                }
            } else {
                m.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(m)
    }
}

impl Matches {
    pub fn get(&self, key: &str) -> &str {
        self.values
            .get(key)
            .map(|s| s.as_str())
            .unwrap_or_else(|| panic!("option --{key} was not declared"))
    }

    pub fn usize(&self, key: &str) -> usize {
        self.get(key)
            .parse()
            .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{}'", self.get(key)))
    }

    pub fn u64(&self, key: &str) -> u64 {
        self.get(key)
            .parse()
            .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{}'", self.get(key)))
    }

    pub fn f64(&self, key: &str) -> f64 {
        self.get(key)
            .parse()
            .unwrap_or_else(|_| panic!("--{key} expects a number, got '{}'", self.get(key)))
    }

    pub fn f32(&self, key: &str) -> f32 {
        self.f64(key) as f32
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("rounds", "100", "rounds")
            .opt("method", "transe", "kge method")
            .flag("verbose", "verbose output")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let m = cli().parse(&args(&[])).unwrap();
        assert_eq!(m.usize("rounds"), 100);
        assert_eq!(m.get("method"), "transe");
        assert!(!m.flag("verbose"));
    }

    #[test]
    fn overrides_and_flags() {
        let m = cli()
            .parse(&args(&["--rounds", "5", "--verbose", "--method=rotate", "pos1"]))
            .unwrap();
        assert_eq!(m.usize("rounds"), 5);
        assert_eq!(m.get("method"), "rotate");
        assert!(m.flag("verbose"));
        assert_eq!(m.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&args(&["--nope", "1"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cli().parse(&args(&["--rounds"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cli().parse(&args(&["--help"])).unwrap_err();
        assert!(err.contains("--rounds"));
    }
}
