//! Tiny declarative CLI parser (clap is unavailable offline; DESIGN.md §5).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional args
//! and auto-generated `--help`.  Parsing and value access are `Result`-based
//! throughout: malformed values and undeclared keys surface as usage errors
//! (carrying the relevant `--help` text) instead of panicking, so `main.rs`
//! can turn them into exit-code-2 failures.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Default)]
pub struct Cli {
    pub name: String,
    pub about: String,
    specs: Vec<ArgSpec>,
}

/// How a parse can end without matches: the user asked for help, or the
/// arguments were unusable.  Both carry the text to show.
#[derive(Debug, Clone)]
pub enum CliError {
    /// `--help`/`-h`: print to stdout and exit 0.
    Help(String),
    /// Bad arguments: print to stderr and exit 2.
    Usage(String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::Help(s) | CliError::Usage(s) => f.write_str(s),
        }
    }
}

impl std::error::Error for CliError {}

#[derive(Debug, Clone)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// option keys the user actually passed (not defaults) — lets callers
    /// treat present flags as overrides
    explicit_keys: Vec<String>,
    pub positional: Vec<String>,
    usage: String,
}

impl Cli {
    pub fn new(name: &str, about: &str) -> Self {
        Self { name: name.into(), about: about.into(), specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for spec in &self.specs {
            let tail = if spec.is_flag {
                String::new()
            } else {
                format!(" <val>  (default: {})", spec.default.as_deref().unwrap_or(""))
            };
            s.push_str(&format!("  --{}{}\n      {}\n", spec.name, tail, spec.help));
        }
        s
    }

    fn usage_err(&self, msg: String) -> CliError {
        CliError::Usage(format!("{msg}\n\n{}", self.usage()))
    }

    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut m = Matches {
            values: BTreeMap::new(),
            flags: Vec::new(),
            explicit_keys: Vec::new(),
            positional: Vec::new(),
            usage: self.usage(),
        };
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                m.values.insert(spec.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::Help(self.usage()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| self.usage_err(format!("unknown option --{key}")))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(self.usage_err(format!("--{key} is a flag and takes no value")));
                    }
                    m.flags.push(key.clone());
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| self.usage_err(format!("--{key} requires a value")))?
                        }
                    };
                    m.values.insert(key.clone(), v);
                }
                m.explicit_keys.push(key);
            } else {
                m.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(m)
    }
}

impl Matches {
    fn usage_err(&self, msg: String) -> String {
        format!("{msg}\n\n{}", self.usage)
    }

    /// The value of a declared option (its default when not passed).
    pub fn get(&self, key: &str) -> Result<&str, String> {
        self.values
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| self.usage_err(format!("option --{key} was not declared")))
    }

    pub fn usize(&self, key: &str) -> Result<usize, String> {
        let v = self.get(key)?;
        v.parse()
            .map_err(|_| self.usage_err(format!("--{key} expects an integer, got '{v}'")))
    }

    pub fn u64(&self, key: &str) -> Result<u64, String> {
        let v = self.get(key)?;
        v.parse()
            .map_err(|_| self.usage_err(format!("--{key} expects an integer, got '{v}'")))
    }

    pub fn f64(&self, key: &str) -> Result<f64, String> {
        let v = self.get(key)?;
        v.parse()
            .map_err(|_| self.usage_err(format!("--{key} expects a number, got '{v}'")))
    }

    pub fn f32(&self, key: &str) -> Result<f32, String> {
        self.f64(key).map(|v| v as f32)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// The value of `key` only if the user passed it explicitly (spec-file
    /// override semantics: defaults don't clobber the spec).
    pub fn explicit(&self, key: &str) -> Option<&str> {
        if self.explicit_keys.iter().any(|k| k == key) {
            self.values.get(key).map(|s| s.as_str())
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("rounds", "100", "rounds")
            .opt("method", "transe", "kge method")
            .flag("verbose", "verbose output")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let m = cli().parse(&args(&[])).unwrap();
        assert_eq!(m.usize("rounds").unwrap(), 100);
        assert_eq!(m.get("method").unwrap(), "transe");
        assert!(!m.flag("verbose"));
        assert!(m.explicit("rounds").is_none(), "defaults are not explicit");
    }

    #[test]
    fn overrides_and_flags() {
        let m = cli()
            .parse(&args(&["--rounds", "5", "--verbose", "--method=rotate", "pos1"]))
            .unwrap();
        assert_eq!(m.usize("rounds").unwrap(), 5);
        assert_eq!(m.get("method").unwrap(), "rotate");
        assert!(m.flag("verbose"));
        assert_eq!(m.positional, vec!["pos1"]);
        assert_eq!(m.explicit("rounds"), Some("5"));
        assert_eq!(m.explicit("method"), Some("rotate"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(matches!(
            cli().parse(&args(&["--nope", "1"])),
            Err(CliError::Usage(_))
        ));
    }

    #[test]
    fn missing_value_errors() {
        assert!(matches!(cli().parse(&args(&["--rounds"])), Err(CliError::Usage(_))));
    }

    #[test]
    fn help_returns_usage() {
        let Err(CliError::Help(text)) = cli().parse(&args(&["--help"])) else {
            panic!("--help must yield CliError::Help");
        };
        assert!(text.contains("--rounds"));
    }

    #[test]
    fn malformed_value_is_usage_error_not_panic() {
        let m = cli().parse(&args(&["--rounds", "abc"])).unwrap();
        let err = m.usize("rounds").unwrap_err();
        assert!(err.contains("expects an integer"), "{err}");
        assert!(err.contains("--rounds"), "error carries the usage text: {err}");
    }

    #[test]
    fn undeclared_key_is_usage_error_not_panic() {
        let m = cli().parse(&args(&[])).unwrap();
        assert!(m.get("nope").is_err());
        assert!(m.usize("nope").is_err());
    }
}
