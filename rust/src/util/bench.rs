//! Micro-benchmark harness (criterion is unavailable offline; DESIGN.md §5).
//!
//! Usage in a `[[bench]] harness = false` target:
//! ```ignore
//! let mut b = Bench::from_env("micro");
//! b.bench("topk/4096", || topk(&scores, 1024));
//! b.finish();
//! ```
//! Prints criterion-style lines (`name  time: [p10 mean p90]`) and writes a
//! JSON report under `reports/bench/` for EXPERIMENTS.md §Perf.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::json::Json;

pub use std::hint::black_box as bb;

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: u64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
}

pub struct Bench {
    suite: String,
    results: Vec<(String, Stats)>,
    /// Target time per benchmark (seconds).
    pub target_time: f64,
    pub warmup_time: f64,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        Self {
            suite: suite.to_string(),
            results: Vec::new(),
            target_time: 1.0,
            warmup_time: 0.3,
        }
    }

    /// Honors FEDS_BENCH_FAST=1 for CI smoke runs.
    pub fn from_env(suite: &str) -> Self {
        let mut b = Self::new(suite);
        if std::env::var("FEDS_BENCH_FAST").as_deref() == Ok("1") {
            b.target_time = 0.15;
            b.warmup_time = 0.05;
        }
        b
    }

    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Stats {
        // warmup + calibration
        let start = Instant::now();
        let mut calib_iters = 0u64;
        while start.elapsed() < Duration::from_secs_f64(self.warmup_time) {
            black_box(f());
            calib_iters += 1;
        }
        let per_iter = self.warmup_time / calib_iters.max(1) as f64;
        let batch = ((0.01 / per_iter) as u64).clamp(1, 1_000_000);
        let samples_target = ((self.target_time / (per_iter * batch as f64)) as usize).clamp(10, 500);

        let mut samples = Vec::with_capacity(samples_target);
        for _ in 0..samples_target {
            let t = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let stats = Stats {
            iters: batch * samples.len() as u64,
            mean_ns: mean,
            p10_ns: pick(0.1),
            p50_ns: pick(0.5),
            p90_ns: pick(0.9),
        };
        println!(
            "{:<48} time: [{} {} {}]  ({} iters)",
            format!("{}/{}", self.suite, name),
            fmt_ns(stats.p10_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p90_ns),
            stats.iters
        );
        self.results.push((name.to_string(), stats.clone()));
        stats
    }

    /// Throughput-style report line for end-to-end benches.
    pub fn report_value(&mut self, name: &str, value: f64, unit: &str) {
        println!("{:<48} {:>12.3} {}", format!("{}/{}", self.suite, name), value, unit);
        self.results.push((
            name.to_string(),
            Stats { iters: 1, mean_ns: value, p10_ns: value, p50_ns: value, p90_ns: value },
        ));
    }

    pub fn finish(self) {
        let dir = std::path::Path::new("reports/bench");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let entries: Vec<Json> = self
            .results
            .iter()
            .map(|(name, s)| {
                Json::obj()
                    .set("name", name.as_str())
                    .set("mean_ns", s.mean_ns)
                    .set("p10_ns", s.p10_ns)
                    .set("p50_ns", s.p50_ns)
                    .set("p90_ns", s.p90_ns)
                    .set("iters", s.iters)
            })
            .collect();
        let j = Json::obj()
            .set("suite", self.suite.as_str())
            .set("results", Json::Arr(entries));
        let _ = std::fs::write(dir.join(format!("{}.json", self.suite)), j.to_string_pretty());
    }
}

/// Write a bench trajectory point as `<name>.json` in the working directory
/// (the `rust/` crate root under `cargo bench`, where CI picks it up as an
/// artifact) and, when `FEDS_BENCH_SNAPSHOT=1` and the repo root is visible
/// one level up, refresh the committed root copy too.
///
/// The env gate matters: CI smoke runs produce fast-mode numbers and must
/// not clobber the committed baseline that `scripts/bench_gate.py` compares
/// them against. Only `scripts/bench_snapshot.sh` (a deliberate full-length
/// run) sets the variable.
pub fn write_trajectory(name: &str, json: &Json) {
    let body = json.to_string_pretty();
    let file = format!("{name}.json");
    if let Err(e) = std::fs::write(&file, &body) {
        eprintln!("warning: could not write {file}: {e}");
    }
    if std::env::var("FEDS_BENCH_SNAPSHOT").as_deref() == Ok("1") {
        let root = std::path::Path::new("..");
        if root.join("ROADMAP.md").is_file() {
            let dst = root.join(&file);
            if let Err(e) = std::fs::write(&dst, &body) {
                eprintln!("warning: could not write {}: {e}", dst.display());
            }
        }
    }
}

/// Peak resident-set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`), or `None` where procfs is unavailable.  This is
/// the high-water mark since process start — the number the scale
/// benchmark gates on to show mmap-backed tables stay O(touched rows).
pub fn peak_rss_bytes() -> Option<u64> {
    proc_status_kib("VmHWM:").map(|kib| kib * 1024)
}

/// Current resident-set size in bytes (`VmRSS`), or `None` off-Linux.
pub fn current_rss_bytes() -> Option<u64> {
    proc_status_kib("VmRSS:").map(|kib| kib * 1024)
}

/// A `kB` field from `/proc/self/status` (Linux only; `None` elsewhere).
fn proc_status_kib(field: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with(field))?;
    line[field.len()..]
        .trim()
        .trim_end_matches("kB")
        .trim()
        .parse::<u64>()
        .ok()
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::new("test");
        b.target_time = 0.05;
        b.warmup_time = 0.01;
        let s = b.bench("noop_sum", || (0..100u64).sum::<u64>());
        assert!(s.mean_ns > 0.0);
        assert!(s.iters > 0);
    }

    #[test]
    fn rss_readings_are_sane_on_linux() {
        if !std::path::Path::new("/proc/self/status").exists() {
            assert!(peak_rss_bytes().is_none());
            assert!(current_rss_bytes().is_none());
            return;
        }
        let cur = current_rss_bytes().expect("VmRSS must parse on Linux");
        let peak = peak_rss_bytes().expect("VmHWM must parse on Linux");
        assert!(cur > 0 && peak >= cur, "peak {peak} must be ≥ current {cur} > 0");
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2_000_000_000.0).contains(" s"));
    }
}
