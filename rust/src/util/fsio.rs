//! Durable filesystem primitives shared by coordinator checkpoints and
//! mmap-backed embedding stores.
//!
//! One discipline everywhere: a snapshot is written to `<file>.tmp`,
//! fsynced, then renamed over the target.  A crash mid-write leaves the
//! previous file intact; readers never observe a torn write.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// The temp sibling a file is staged through: `<path>.tmp`.
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Atomically replace `path` with `bytes`: write `<path>.tmp`, fsync,
/// rename.  Returns the byte count written.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<u64> {
    let tmp = tmp_path(path);
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    Ok(bytes.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_write_replaces_without_leaving_tmp() {
        let dir = std::env::temp_dir().join(format!("feds-fsio-test-{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let target = dir.join("state.bin");
        assert_eq!(atomic_write(&target, b"first").unwrap(), 5);
        assert_eq!(fs::read(&target).unwrap(), b"first");
        assert_eq!(atomic_write(&target, b"second!").unwrap(), 7);
        assert_eq!(fs::read(&target).unwrap(), b"second!");
        assert!(!tmp_path(&target).exists(), "temp staged file must be renamed away");
        let _ = fs::remove_dir_all(&dir);
    }
}
