//! Pluggable embedding storage: the seam that decouples every
//! O(entities × width) f32 table from `Vec<f32>`.
//!
//! The paper's scaling argument (§III, Eq. 5) is that FedS's per-round
//! cost tracks the Top-K **touched** entities, not the table size — but a
//! `Vec`-backed table still pins O(E·W) resident memory per replica, which
//! caps experiments near E = 50k.  [`EmbedStore`] abstracts a
//! row-addressable f32 table behind two backends:
//!
//! * [`VecStore`] — the historical in-RAM table (the default; bit-identical
//!   to the pre-store engine by construction).
//! * [`MmapStore`] — a file-backed memory mapping.  Zero-initialized
//!   tables are sparse files, so a page becomes resident only when a row
//!   is actually read or written through the map: resident memory scales
//!   with the **touched** rows, matching the paper's cost model.  Flushes
//!   follow the coordinator-checkpoint discipline (msync + fsync; atomic
//!   snapshots via write-tmp → fsync → rename).
//!
//! [`StoreTable`] wraps a boxed store behind the same `row`/`row_mut`/
//! `set_row` surface as [`crate::kge::Table`], caching the store's stable
//! buffer pointer so hot-path row access costs exactly a bounds check plus
//! a slice construction — no virtual dispatch per row.  Both backends
//! expose the same contiguous row-major buffer, so results are
//! **bit-identical** across backends for every algorithm.
//!
//! Concurrency matches the scoped-thread model of [`crate::fed::server`]:
//! a store is `Sync` (shared reads) and disjoint shard ranges can be
//! mutated in parallel through [`EmbedStore::ranges_mut`] /
//! `split_at_mut`-style views.
//!
//! [`StorageSpec`] is the serializable selector carried by
//! `ExperimentSpec` (`--store ram|mmap|mmap:<dir>`).

pub mod mmap;

use std::path::PathBuf;

use anyhow::Result;

use crate::util::rng::Rng;

pub use mmap::MmapStore;

/// A row-addressable f32 table: `rows × width`, contiguous row-major.
///
/// Implementations own a stable buffer — the pointer returned by
/// `as_slice`/`as_mut_slice` must not move for the lifetime of the store
/// (no reallocation), which is what lets [`StoreTable`] cache it.
pub trait EmbedStore: Send + Sync {
    /// Number of rows.
    fn rows(&self) -> usize;

    /// Row width in f32 elements.
    fn width(&self) -> usize;

    /// The whole table as one contiguous row-major slice.
    fn as_slice(&self) -> &[f32];

    /// Mutable view of the whole table.
    fn as_mut_slice(&mut self) -> &mut [f32];

    /// Make written data durable (no-op for RAM; msync + fsync for mmap).
    fn flush(&mut self) -> Result<()>;

    /// Backend name for logs and bench points.
    fn backend(&self) -> &'static str;

    /// An independent copy of this store's contents on the same backend.
    /// Panics on backend I/O failure (cloning is infallible by signature
    /// because model state derives `Clone`).
    fn clone_store(&self) -> Box<dyn EmbedStore>;

    /// Row `id` (panics when `id >= rows`).
    fn row(&self, id: usize) -> &[f32] {
        let w = self.width();
        assert!(id < self.rows(), "row {id} out of range ({} rows)", self.rows());
        &self.as_slice()[id * w..(id + 1) * w]
    }

    /// Mutable row `id` (panics when `id >= rows`).
    fn row_mut(&mut self, id: usize) -> &mut [f32] {
        let w = self.width();
        assert!(id < self.rows(), "row {id} out of range ({} rows)", self.rows());
        &mut self.as_mut_slice()[id * w..(id + 1) * w]
    }

    /// Scatter `data` (concatenated rows, `ids.len() × width`) into the
    /// table.  Panics on id out of range or size mismatch.
    fn write_rows(&mut self, ids: &[u32], data: &[f32]) {
        let w = self.width();
        assert_eq!(data.len(), ids.len() * w, "write_rows size mismatch");
        for (k, &id) in ids.iter().enumerate() {
            self.row_mut(id as usize).copy_from_slice(&data[k * w..(k + 1) * w]);
        }
    }

    /// Disjoint mutable row-range views, one per consecutive pair of
    /// `cuts` (row indices, ascending, first 0 and last `rows`) — the
    /// shard-range decomposition used for safe concurrent writes from
    /// scoped threads.
    fn ranges_mut(&mut self, cuts: &[usize]) -> Vec<&mut [f32]> {
        let w = self.width();
        assert!(cuts.first() == Some(&0) && cuts.last() == Some(&self.rows()));
        let mut rest = self.as_mut_slice();
        let mut segs = Vec::with_capacity(cuts.len().saturating_sub(1));
        for s in 0..cuts.len() - 1 {
            assert!(cuts[s] <= cuts[s + 1], "range cuts must ascend");
            let (seg, tail) = std::mem::take(&mut rest).split_at_mut((cuts[s + 1] - cuts[s]) * w);
            segs.push(seg);
            rest = tail;
        }
        segs
    }
}

/// The historical in-RAM backend: a plain `Vec<f32>`.
pub struct VecStore {
    rows: usize,
    width: usize,
    data: Vec<f32>,
}

impl VecStore {
    pub fn zeros(rows: usize, width: usize) -> Self {
        Self { rows, width, data: vec![0.0; rows * width] }
    }

    pub fn from_vec(rows: usize, width: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * width, "VecStore shape mismatch");
        Self { rows, width, data }
    }
}

impl EmbedStore for VecStore {
    fn rows(&self) -> usize {
        self.rows
    }

    fn width(&self) -> usize {
        self.width
    }

    fn as_slice(&self) -> &[f32] {
        &self.data
    }

    fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    fn flush(&mut self) -> Result<()> {
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "ram"
    }

    fn clone_store(&self) -> Box<dyn EmbedStore> {
        Box::new(VecStore { rows: self.rows, width: self.width, data: self.data.clone() })
    }
}

/// Which backend a run's O(entities × width) tables live on.  Serialized
/// as a label: `"ram"`, `"mmap"`, or `"mmap:<dir>"`.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum StorageSpec {
    /// In-RAM `Vec<f32>` tables (the default, and the historical behavior).
    #[default]
    Ram,
    /// File-backed memory-mapped tables; scratch files live in `dir`
    /// (the system temp directory when `None`).
    Mmap { dir: Option<String> },
}

impl StorageSpec {
    pub fn parse(s: &str) -> Result<StorageSpec> {
        let lower = s.to_ascii_lowercase();
        if let Some(dir) = lower.strip_prefix("mmap:") {
            anyhow::ensure!(!dir.is_empty(), "empty mmap directory in '--store {s}'");
            // preserve the caller's casing for the path itself
            return Ok(StorageSpec::Mmap { dir: Some(s["mmap:".len()..].to_string()) });
        }
        match lower.as_str() {
            "ram" | "mem" | "vec" => Ok(StorageSpec::Ram),
            "mmap" => Ok(StorageSpec::Mmap { dir: None }),
            other => anyhow::bail!("unknown storage backend '{other}' (ram|mmap|mmap:<dir>)"),
        }
    }

    pub fn label(&self) -> String {
        match self {
            StorageSpec::Ram => "ram".to_string(),
            StorageSpec::Mmap { dir: None } => "mmap".to_string(),
            StorageSpec::Mmap { dir: Some(d) } => format!("mmap:{d}"),
        }
    }

    pub fn is_mmap(&self) -> bool {
        matches!(self, StorageSpec::Mmap { .. })
    }

    /// Directory scratch stores are created in.
    pub fn dir(&self) -> PathBuf {
        match self {
            StorageSpec::Mmap { dir: Some(d) } => PathBuf::from(d),
            _ => std::env::temp_dir(),
        }
    }

    /// An all-zero `rows × width` store on this backend.  Mmap stores are
    /// sparse: no page is resident (or on disk) until a row is touched.
    pub fn open_zeroed(&self, rows: usize, width: usize) -> Result<Box<dyn EmbedStore>> {
        Ok(match self {
            StorageSpec::Ram => Box::new(VecStore::zeros(rows, width)),
            StorageSpec::Mmap { .. } => Box::new(MmapStore::scratch(&self.dir(), rows, width)?),
        })
    }

    /// A store initialized row-by-row by `fill` (called once per row, in
    /// row order).  The mmap backend streams rows through buffered file
    /// writes **before** mapping, so initialization lands in the page
    /// cache without making the table resident in this process.
    pub fn open_init(
        &self,
        rows: usize,
        width: usize,
        fill: &mut dyn FnMut(usize, &mut [f32]),
    ) -> Result<Box<dyn EmbedStore>> {
        Ok(match self {
            StorageSpec::Ram => {
                let mut data = vec![0.0f32; rows * width];
                for (r, chunk) in data.chunks_exact_mut(width).enumerate() {
                    fill(r, chunk);
                }
                Box::new(VecStore::from_vec(rows, width, data))
            }
            StorageSpec::Mmap { .. } => {
                Box::new(MmapStore::scratch_init(&self.dir(), rows, width, fill)?)
            }
        })
    }
}

/// A `Table`-shaped wrapper over a boxed [`EmbedStore`]: same
/// `row`/`row_mut`/`set_row` surface, plus a cached pointer to the store's
/// stable buffer so per-row access involves no virtual dispatch — the
/// training hot path pays exactly what it paid with `Vec`-backed tables.
pub struct StoreTable {
    pub rows: usize,
    pub width: usize,
    store: Box<dyn EmbedStore>,
    /// cached `store` buffer; stable because stores never reallocate
    ptr: *mut f32,
    len: usize,
}

// Safety: `ptr` aliases only the buffer owned by `store` (which is
// `Send + Sync`); `&self` methods read, `&mut self` methods write, so the
// usual reference rules police all access.
unsafe impl Send for StoreTable {}
unsafe impl Sync for StoreTable {}

impl StoreTable {
    pub fn from_store(mut store: Box<dyn EmbedStore>) -> Self {
        let (rows, width) = (store.rows(), store.width());
        let buf = store.as_mut_slice();
        let (ptr, len) = (buf.as_mut_ptr(), buf.len());
        Self { rows, width, store, ptr, len }
    }

    /// In-RAM zero table — drop-in for `Table::zeros`.
    pub fn zeros(rows: usize, width: usize) -> Self {
        Self::from_store(Box::new(VecStore::zeros(rows, width)))
    }

    /// Zero table on the selected backend (sparse for mmap).
    pub fn zeros_in(spec: &StorageSpec, rows: usize, width: usize) -> Result<Self> {
        Ok(Self::from_store(spec.open_zeroed(rows, width)?))
    }

    /// In-RAM table over an existing buffer.
    pub fn from_vec(rows: usize, width: usize, data: Vec<f32>) -> Self {
        Self::from_store(Box::new(VecStore::from_vec(rows, width, data)))
    }

    /// Uniform init in ±range on the selected backend.  Draws from `rng`
    /// element-by-element in row-major order — the exact sequence of
    /// `Table::init_uniform` — so backends are bit-identical.
    pub fn init_uniform_in(
        spec: &StorageSpec,
        rows: usize,
        width: usize,
        range: f32,
        rng: &mut Rng,
    ) -> Result<Self> {
        let mut fill = |_r: usize, row: &mut [f32]| {
            for x in row.iter_mut() {
                *x = rng.uniform(-range, range);
            }
        };
        Ok(Self::from_store(spec.open_init(rows, width, &mut fill)?))
    }

    /// Total element count (`rows * width`).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        assert!(i < self.rows, "row {i} out of range ({} rows)", self.rows);
        unsafe { std::slice::from_raw_parts(self.ptr.add(i * self.width), self.width) }
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert!(i < self.rows, "row {i} out of range ({} rows)", self.rows);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(i * self.width), self.width) }
    }

    pub fn set_row(&mut self, i: usize, v: &[f32]) {
        self.row_mut(i).copy_from_slice(v);
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    pub fn iter(&self) -> std::slice::Iter<'_, f32> {
        self.as_slice().iter()
    }

    pub fn to_vec(&self) -> Vec<f32> {
        self.as_slice().to_vec()
    }

    pub fn flush(&mut self) -> Result<()> {
        self.store.flush()
    }

    pub fn backend(&self) -> &'static str {
        self.store.backend()
    }
}

impl Clone for StoreTable {
    fn clone(&self) -> Self {
        Self::from_store(self.store.clone_store())
    }
}

impl std::fmt::Debug for StoreTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StoreTable")
            .field("rows", &self.rows)
            .field("width", &self.width)
            .field("backend", &self.store.backend())
            .finish()
    }
}

impl std::ops::Index<usize> for StoreTable {
    type Output = f32;

    fn index(&self, i: usize) -> &f32 {
        &self.as_slice()[i]
    }
}

impl PartialEq for StoreTable {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows && self.width == other.width && self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Vec<f32>> for StoreTable {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_dir() -> PathBuf {
        let d = std::env::temp_dir().join(format!("feds-store-test-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn backends() -> Vec<StorageSpec> {
        vec![
            StorageSpec::Ram,
            StorageSpec::Mmap { dir: Some(scratch_dir().to_string_lossy().into_owned()) },
        ]
    }

    #[test]
    fn spec_parse_and_label_round_trip() {
        assert_eq!(StorageSpec::parse("ram").unwrap(), StorageSpec::Ram);
        assert_eq!(StorageSpec::parse("mmap").unwrap(), StorageSpec::Mmap { dir: None });
        assert_eq!(
            StorageSpec::parse("mmap:/tmp/x").unwrap(),
            StorageSpec::Mmap { dir: Some("/tmp/x".to_string()) }
        );
        for s in ["ram", "mmap", "mmap:/tmp/x"] {
            assert_eq!(StorageSpec::parse(s).unwrap().label(), s);
        }
        assert!(StorageSpec::parse("tape").is_err());
        assert!(StorageSpec::parse("mmap:").is_err());
    }

    /// Contract: zeroed stores read back zero, writes read back exactly,
    /// and both backends agree bit-for-bit.
    #[test]
    fn contract_zeroed_write_read_all_backends() {
        for spec in backends() {
            let mut t = StoreTable::zeros_in(&spec, 7, 3).unwrap();
            assert_eq!(t.rows, 7);
            assert_eq!(t.width, 3);
            assert!(t.as_slice().iter().all(|&x| x == 0.0), "{}", t.backend());
            t.set_row(2, &[1.0, 2.0, 3.0]);
            t.row_mut(6)[1] = -4.5;
            assert_eq!(t.row(2), &[1.0, 2.0, 3.0]);
            assert_eq!(t.row(6), &[0.0, -4.5, 0.0]);
            assert_eq!(t.row(0), &[0.0, 0.0, 0.0]);
            let copy = t.clone();
            assert_eq!(copy, t, "{}", t.backend());
        }
    }

    #[test]
    fn contract_init_uniform_identical_across_backends() {
        let (rows, width, range) = (13, 5, 0.25f32);
        let mut tables = Vec::new();
        for spec in backends() {
            let mut rng = Rng::new(99);
            tables.push(StoreTable::init_uniform_in(&spec, rows, width, range, &mut rng).unwrap());
        }
        let bits = |t: &StoreTable| t.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&tables[0]), bits(&tables[1]));
        assert!(tables[0].iter().all(|&x| (-range..range).contains(&x)));
    }

    #[test]
    fn contract_out_of_range_row_panics() {
        for spec in backends() {
            let t = StoreTable::zeros_in(&spec, 4, 2).unwrap();
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.row(4).len()));
            assert!(r.is_err(), "row(4) of a 4-row {} store must panic", t.backend());
        }
    }

    #[test]
    fn contract_out_of_range_write_rows_panics() {
        for spec in backends() {
            let mut store = spec.open_zeroed(4, 2).unwrap();
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                store.write_rows(&[9], &[1.0, 2.0]);
            }));
            assert!(r.is_err(), "write_rows(9) on a 4-row store must panic");
        }
    }

    /// Contract: disjoint shard ranges of one store can be mutated from
    /// scoped threads — the `fed::server` concurrency model.
    #[test]
    fn contract_disjoint_shard_ranges_mutate_concurrently() {
        for spec in backends() {
            let rows = 64;
            let width = 4;
            let mut store = spec.open_zeroed(rows, width).unwrap();
            let cuts = [0usize, 17, 40, 64];
            {
                let segs = store.ranges_mut(&cuts);
                std::thread::scope(|s| {
                    for (shard, seg) in segs.into_iter().enumerate() {
                        s.spawn(move || {
                            for x in seg.iter_mut() {
                                *x = (shard + 1) as f32;
                            }
                        });
                    }
                });
            }
            for r in 0..rows {
                let shard = cuts.iter().position(|&c| r < c).unwrap(); // 1-based
                let want = shard as f32;
                assert!(
                    store.row(r).iter().all(|&x| x == want),
                    "row {r}: {:?} want {want} ({})",
                    store.row(r),
                    store.backend()
                );
            }
        }
    }

    #[test]
    fn write_rows_scatter_matches_set_row() {
        for spec in backends() {
            let mut a = spec.open_zeroed(10, 2).unwrap();
            let mut b = StoreTable::zeros_in(&spec, 10, 2).unwrap();
            let ids = [1u32, 4, 9];
            let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
            a.write_rows(&ids, &data);
            for (k, &id) in ids.iter().enumerate() {
                b.set_row(id as usize, &data[k * 2..(k + 1) * 2]);
            }
            assert_eq!(a.as_slice(), b.as_slice());
        }
    }

    #[test]
    fn store_table_partial_eq_vec() {
        let mut t = StoreTable::zeros(2, 2);
        t.set_row(1, &[3.0, 4.0]);
        assert_eq!(t, vec![0.0, 0.0, 3.0, 4.0]);
        assert_eq!(t[3], 4.0);
    }

    #[test]
    fn empty_table_is_safe() {
        for spec in backends() {
            let t = StoreTable::zeros_in(&spec, 0, 4).unwrap();
            assert!(t.is_empty());
            assert!(t.as_slice().is_empty());
        }
    }
}
