//! File-backed embedding storage via `mmap`.
//!
//! Layout: one 4096-byte header page (magic `FEDSSTO1`, rows, width as
//! little-endian u64) followed by `rows × width` native-endian f32s, so
//! row data starts page-aligned.  Files are single-host artifacts (the
//! map reinterprets process memory), hence native data endianness.
//!
//! Two lifetimes:
//!
//! * **Scratch** stores ([`MmapStore::scratch`] / `scratch_init`) back
//!   run-time tables.  The file is created, sized with `set_len` (a
//!   sparse file — untouched pages read as zeros and cost nothing on
//!   disk or in RSS), mapped, then **unlinked**: the mapping keeps it
//!   alive, and the kernel reclaims it the moment the process exits,
//!   crashed or not.  Streaming init writes rows through a `BufWriter`
//!   *before* mapping, so initialization lands in page cache without
//!   making the table resident in this process.
//! * **Named** stores ([`MmapStore::create`] / [`MmapStore::open`])
//!   persist across drops.  [`MmapStore::flush`] is msync + fsync;
//!   [`MmapStore::save_copy`] snapshots atomically through the same
//!   write-tmp → fsync → rename discipline as coordinator checkpoints
//!   ([`crate::util::fsio::atomic_write`]).
//!
//! The real mapping is Linux-only (raw `mmap`/`munmap`/`msync` FFI — no
//! external crates are available).  Other platforms get a portable
//! file-loaded `Vec` backing with identical semantics minus the residency
//! savings.

use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use super::EmbedStore;
use crate::util::fsio;

/// `"FEDSSTO1"` as a little-endian u64 — the first eight bytes on disk.
const MAGIC: u64 = u64::from_le_bytes(*b"FEDSSTO1");
/// One page: keeps the f32 data region page-aligned.
const HEADER_BYTES: usize = 4096;

/// Distinguishes concurrently created scratch files within one process.
static SCRATCH_SEQ: AtomicU64 = AtomicU64::new(0);

fn header(rows: usize, width: usize) -> Box<[u8; HEADER_BYTES]> {
    let mut h = Box::new([0u8; HEADER_BYTES]);
    h[..8].copy_from_slice(&MAGIC.to_le_bytes());
    h[8..16].copy_from_slice(&(rows as u64).to_le_bytes());
    h[16..24].copy_from_slice(&(width as u64).to_le_bytes());
    h
}

fn total_bytes(rows: usize, width: usize) -> u64 {
    HEADER_BYTES as u64 + (rows * width * 4) as u64
}

#[cfg(target_os = "linux")]
mod backing {
    //! A shared writable mapping of an open file (raw libc FFI).

    use std::fs::File;
    use std::os::raw::{c_int, c_void};
    use std::os::unix::io::AsRawFd;

    use anyhow::{bail, Result};

    use super::HEADER_BYTES;

    const PROT_READ: c_int = 1;
    const PROT_WRITE: c_int = 2;
    const MAP_SHARED: c_int = 1;
    /// Linux value; macOS uses 0x0010 — one reason this module is gated.
    const MS_SYNC: c_int = 4;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
        fn msync(addr: *mut c_void, len: usize, flags: c_int) -> c_int;
    }

    pub struct Backing {
        /// Keeps the (possibly unlinked) file alive alongside the map.
        file: File,
        ptr: *mut u8,
        byte_len: usize,
        elems: usize,
    }

    // Safety: the mapping is exclusively owned; all access goes through
    // `&self`/`&mut self` methods, so the borrow checker polices aliasing.
    unsafe impl Send for Backing {}
    unsafe impl Sync for Backing {}

    impl Backing {
        /// Map `file`, already sized to header + `elems` f32s.
        pub fn over_file(file: File, elems: usize) -> Result<Self> {
            let byte_len = HEADER_BYTES + elems * 4;
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    byte_len,
                    PROT_READ | PROT_WRITE,
                    MAP_SHARED,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr == usize::MAX as *mut c_void {
                bail!("mmap of {byte_len} bytes failed: {}", std::io::Error::last_os_error());
            }
            Ok(Self { file, ptr: ptr as *mut u8, byte_len, elems })
        }

        pub fn as_f32(&self) -> &[f32] {
            unsafe {
                std::slice::from_raw_parts(self.ptr.add(HEADER_BYTES) as *const f32, self.elems)
            }
        }

        pub fn as_f32_mut(&mut self) -> &mut [f32] {
            unsafe {
                std::slice::from_raw_parts_mut(self.ptr.add(HEADER_BYTES) as *mut f32, self.elems)
            }
        }

        pub fn flush(&mut self) -> Result<()> {
            let rc = unsafe { msync(self.ptr as *mut c_void, self.byte_len, MS_SYNC) };
            if rc != 0 {
                bail!("msync failed: {}", std::io::Error::last_os_error());
            }
            self.file.sync_all()?;
            Ok(())
        }
    }

    impl Drop for Backing {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr as *mut c_void, self.byte_len);
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod backing {
    //! Portable fallback: the table lives in a `Vec`, loaded from and
    //! flushed back to the file.  Same durability contract, no residency
    //! savings.

    use std::fs::File;
    use std::io::{Read as _, Seek as _, SeekFrom, Write as _};

    use anyhow::Result;

    use super::HEADER_BYTES;

    pub struct Backing {
        file: File,
        data: Vec<f32>,
    }

    impl Backing {
        pub fn over_file(mut file: File, elems: usize) -> Result<Self> {
            file.seek(SeekFrom::Start(HEADER_BYTES as u64))?;
            let mut bytes = vec![0u8; elems * 4];
            file.read_exact(&mut bytes)?;
            let data = bytes
                .chunks_exact(4)
                .map(|b| f32::from_ne_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            Ok(Self { file, data })
        }

        pub fn as_f32(&self) -> &[f32] {
            &self.data
        }

        pub fn as_f32_mut(&mut self) -> &mut [f32] {
            &mut self.data
        }

        pub fn flush(&mut self) -> Result<()> {
            self.file.seek(SeekFrom::Start(HEADER_BYTES as u64))?;
            let mut bytes = Vec::with_capacity(self.data.len() * 4);
            for x in &self.data {
                bytes.extend_from_slice(&x.to_ne_bytes());
            }
            self.file.write_all(&bytes)?;
            self.file.sync_all()?;
            Ok(())
        }
    }
}

use backing::Backing;

/// A file-backed `rows × width` f32 table (see module docs).
pub struct MmapStore {
    rows: usize,
    width: usize,
    /// `Some` for named (durable) stores, `None` for unlinked scratch.
    path: Option<PathBuf>,
    /// Where sibling scratch stores (clones) are created.
    dir: PathBuf,
    backing: Backing,
}

impl MmapStore {
    fn scratch_file(dir: &Path) -> Result<(File, PathBuf)> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating store scratch dir {}", dir.display()))?;
        let name = format!(
            "feds-embed-{}-{}.bin",
            std::process::id(),
            SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let path = dir.join(name);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| format!("creating scratch store {}", path.display()))?;
        Ok((file, path))
    }

    /// Finish a fully written scratch file: unlink it (the map keeps it
    /// alive; the kernel reclaims it on process exit) and map it.
    fn seal_scratch(
        file: File,
        path: PathBuf,
        dir: &Path,
        rows: usize,
        width: usize,
    ) -> Result<Self> {
        #[cfg(target_os = "linux")]
        fs::remove_file(&path)
            .with_context(|| format!("unlinking scratch store {}", path.display()))?;
        // The portable backing reads the file contents at map time, so the
        // unlink must come after `over_file` there; keep the file and let
        // Drop leak it rather than complicating the fallback.
        let backing = Backing::over_file(file, rows * width)?;
        #[cfg(not(target_os = "linux"))]
        let _ = fs::remove_file(&path);
        Ok(Self { rows, width, path: None, dir: dir.to_path_buf(), backing })
    }

    /// An all-zero scratch store: sparse file, no page resident until a
    /// row is touched.
    pub fn scratch(dir: &Path, rows: usize, width: usize) -> Result<Self> {
        let (mut file, path) = Self::scratch_file(dir)?;
        file.write_all(&header(rows, width)[..])?;
        file.set_len(total_bytes(rows, width))?;
        Self::seal_scratch(file, path, dir, rows, width)
    }

    /// A scratch store initialized row-by-row by `fill` (row order),
    /// streamed through buffered file writes before mapping.
    pub fn scratch_init(
        dir: &Path,
        rows: usize,
        width: usize,
        fill: &mut dyn FnMut(usize, &mut [f32]),
    ) -> Result<Self> {
        let (file, path) = Self::scratch_file(dir)?;
        {
            let mut w = BufWriter::with_capacity(1 << 20, &file);
            w.write_all(&header(rows, width)[..])?;
            let mut row = vec![0.0f32; width];
            let mut bytes = vec![0u8; width * 4];
            for r in 0..rows {
                fill(r, &mut row);
                for (b, x) in bytes.chunks_exact_mut(4).zip(&row) {
                    b.copy_from_slice(&x.to_ne_bytes());
                }
                w.write_all(&bytes)?;
            }
            w.flush()?;
        }
        Self::seal_scratch(file, path, dir, rows, width)
    }

    /// Create (or truncate) a named durable store, all zeros.
    pub fn create(path: &Path, rows: usize, width: usize) -> Result<Self> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)?;
            }
        }
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("creating store {}", path.display()))?;
        file.write_all(&header(rows, width)[..])?;
        file.set_len(total_bytes(rows, width))?;
        let backing = Backing::over_file(file, rows * width)?;
        let dir = path.parent().map(Path::to_path_buf).unwrap_or_else(std::env::temp_dir);
        Ok(Self { rows, width, path: Some(path.to_path_buf()), dir, backing })
    }

    /// Reopen a named store written by [`MmapStore::create`] (+ flush).
    pub fn open(path: &Path) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("opening store {}", path.display()))?;
        let mut head = [0u8; 24];
        {
            use std::io::Read as _;
            (&file).read_exact(&mut head).context("store header truncated")?;
        }
        let magic = u64::from_le_bytes(head[..8].try_into().unwrap());
        anyhow::ensure!(magic == MAGIC, "{} is not an embedding store", path.display());
        let rows = u64::from_le_bytes(head[8..16].try_into().unwrap()) as usize;
        let width = u64::from_le_bytes(head[16..24].try_into().unwrap()) as usize;
        let want = total_bytes(rows, width);
        let got = file.metadata()?.len();
        anyhow::ensure!(
            got == want,
            "store {} truncated: {got} bytes on disk, header claims {want}",
            path.display()
        );
        let backing = Backing::over_file(file, rows * width)?;
        let dir = path.parent().map(Path::to_path_buf).unwrap_or_else(std::env::temp_dir);
        Ok(Self { rows, width, path: Some(path.to_path_buf()), dir, backing })
    }

    /// Atomic point-in-time snapshot to `path` (write-tmp → fsync →
    /// rename, like coordinator checkpoints).  The result reopens with
    /// [`MmapStore::open`].
    pub fn save_copy(&self, path: &Path) -> Result<()> {
        let data = self.backing.as_f32();
        let mut bytes = Vec::with_capacity(HEADER_BYTES + data.len() * 4);
        bytes.extend_from_slice(&header(self.rows, self.width)[..]);
        // same-host snapshot: native endianness, matching the map
        let view =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        bytes.extend_from_slice(view);
        fsio::atomic_write(path, &bytes)
            .with_context(|| format!("snapshotting store to {}", path.display()))?;
        Ok(())
    }

    /// The named file this store persists to (`None` for scratch).
    pub fn file_path(&self) -> Option<&Path> {
        self.path.as_deref()
    }
}

impl EmbedStore for MmapStore {
    fn rows(&self) -> usize {
        self.rows
    }

    fn width(&self) -> usize {
        self.width
    }

    fn as_slice(&self) -> &[f32] {
        self.backing.as_f32()
    }

    fn as_mut_slice(&mut self) -> &mut [f32] {
        self.backing.as_f32_mut()
    }

    fn flush(&mut self) -> Result<()> {
        self.backing.flush()
    }

    fn backend(&self) -> &'static str {
        "mmap"
    }

    fn clone_store(&self) -> Box<dyn EmbedStore> {
        let mut copy = MmapStore::scratch(&self.dir, self.rows, self.width)
            .expect("cloning an mmap store requires a writable scratch dir");
        copy.as_mut_slice().copy_from_slice(self.as_slice());
        Box::new(copy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("feds-mmap-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn scratch_reads_zero_and_round_trips_writes() {
        let dir = test_dir("scratch");
        let mut s = MmapStore::scratch(&dir, 100, 8).unwrap();
        assert!(s.as_slice().iter().all(|&x| x == 0.0));
        s.row_mut(42)[3] = 7.5;
        assert_eq!(s.row(42)[3], 7.5);
        assert_eq!(s.row(41), &[0.0; 8]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn scratch_file_is_unlinked_immediately() {
        let dir = test_dir("unlink");
        let _s = MmapStore::scratch(&dir, 16, 4).unwrap();
        let leftovers: Vec<_> = fs::read_dir(&dir).unwrap().collect();
        assert!(leftovers.is_empty(), "scratch files must not outlive creation: {leftovers:?}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn named_store_survives_drop_and_reopen() {
        let dir = test_dir("durable");
        let path = dir.join("ent.store");
        {
            let mut s = MmapStore::create(&path, 9, 3).unwrap();
            for r in 0..9 {
                let row: Vec<f32> = (0..3).map(|k| (r * 3 + k) as f32 * 0.5).collect();
                s.row_mut(r).copy_from_slice(&row);
            }
            s.flush().unwrap();
        }
        let s = MmapStore::open(&path).unwrap();
        assert_eq!((s.rows(), s.width()), (9, 3));
        for r in 0..9 {
            let want: Vec<f32> = (0..3).map(|k| (r * 3 + k) as f32 * 0.5).collect();
            assert_eq!(s.row(r), &want[..], "row {r}");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_rejects_foreign_and_truncated_files() {
        let dir = test_dir("reject");
        let bogus = dir.join("bogus.store");
        fs::write(&bogus, b"not a store at all").unwrap();
        assert!(MmapStore::open(&bogus).is_err());
        let path = dir.join("short.store");
        {
            let mut s = MmapStore::create(&path, 4, 4).unwrap();
            s.flush().unwrap();
        }
        let full = fs::read(&path).unwrap();
        fs::write(&path, &full[..full.len() - 4]).unwrap();
        assert!(MmapStore::open(&path).is_err(), "truncated store must be refused");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_copy_snapshots_atomically() {
        let dir = test_dir("snap");
        let mut s = MmapStore::scratch(&dir, 5, 2).unwrap();
        s.row_mut(4).copy_from_slice(&[1.25, -2.0]);
        let snap = dir.join("snap.store");
        s.save_copy(&snap).unwrap();
        assert!(!fsio::tmp_path(&snap).exists());
        let back = MmapStore::open(&snap).unwrap();
        assert_eq!(back.as_slice(), s.as_slice());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn clone_store_is_independent() {
        let dir = test_dir("clone");
        let mut s = MmapStore::scratch(&dir, 3, 2).unwrap();
        s.row_mut(1).copy_from_slice(&[3.0, 4.0]);
        let mut c = s.clone_store();
        assert_eq!(c.as_slice(), s.as_slice());
        c.row_mut(1)[0] = 99.0;
        assert_eq!(s.row(1), &[3.0, 4.0], "clone writes must not alias the source");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_row_store_maps_header_only() {
        let dir = test_dir("empty");
        let s = MmapStore::scratch(&dir, 0, 16).unwrap();
        assert!(s.as_slice().is_empty());
        let _ = fs::remove_dir_all(&dir);
    }
}
