//! # feds — Communication-Efficient Federated Knowledge Graph Embedding
//!
//! A production-shaped reproduction of *"Communication-Efficient Federated
//! Knowledge Graph Embedding with Entity-Wise Top-K Sparsification"*
//! (Zhang et al., 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated coordinator: FedS's Entity-Wise
//!   Top-K sparsification (upstream and downstream), the Intermittent
//!   Synchronization Mechanism, personalized aggregation, baselines
//!   (FedE/FedEP/FedEPL/Single, KD/SVD/SVD+), the metered wire protocol,
//!   and the experiment harness reproducing every table/figure.
//! * **L2/L1 (build-time Python)** — the KGE compute graph and Pallas
//!   scoring kernels, AOT-lowered to HLO text in `artifacts/` and executed
//!   here via PJRT (`runtime`).  Python is never on the training path.
//!
//! See DESIGN.md for the full system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod comm;
pub mod data;
pub mod exp;
pub mod fed;
pub mod kge;
pub mod linalg;
pub mod metrics;
pub mod runtime;
pub mod trainer;
pub mod util;

pub use kge::{Hyper, Method};

/// Crate version (matches Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
