//! # feds — Communication-Efficient Federated Knowledge Graph Embedding
//!
//! A production-shaped reproduction of *"Communication-Efficient Federated
//! Knowledge Graph Embedding with Entity-Wise Top-K Sparsification"*
//! (Zhang et al., 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated coordinator: FedS's Entity-Wise
//!   Top-K sparsification (upstream and downstream), the Intermittent
//!   Synchronization Mechanism, personalized aggregation, baselines
//!   (FedE/FedEP/FedEPL/Single, KD/SVD/SVD+), the metered wire protocol,
//!   and the experiment harness reproducing every table/figure.
//! * **L2/L1 (build-time Python)** — the KGE compute graph and Pallas
//!   scoring kernels, AOT-lowered to HLO text in `artifacts/` and executed
//!   here via PJRT (`runtime`).  Python is never on the training path.
//!
//! ## Module map
//!
//! * [`spec`] — **the public entry point**: the declarative experiment
//!   API.  `ExperimentSpec` is a fully JSON-(de)serializable run
//!   description (data/backend/budget plus an algorithm-scoped `AlgoSpec`
//!   where each variant carries only its own knobs); `Session::build`
//!   turns specs into executable `Run` handles.
//! * [`kge`] — method/table/optimizer definitions and the pure-Rust
//!   reference engine (`kge::native`).  The training hot path is sparse
//!   **and lane-parallel**: touched-row gradients (`SparseGrad`) + lazy
//!   row-wise Adam (`LazyAdam`) make a step O(touched·width), and the
//!   per-pair score/gradient math runs through width-dispatched
//!   autovectorizing kernels (`kge::kernels`, selected once at
//!   construction) with per-positive negative-id dedup.  Two reference
//!   engines are retained for parity — the element-at-a-time loops
//!   behind `KernelSet::scalar()` and the pre-sparse `DenseOracle` —
//!   and `eval_ranks` chunks its candidate scan across OS threads with
//!   bit-identical results (see PERF.md).
//! * [`trainer`] — the `LocalTrainer` seam the federated layer drives:
//!   native oracle, PJRT-backed XLA trainers, and the KD transport.
//! * [`fed`] — the federated layer: Entity-Wise Top-K (`fed::topk`,
//!   partial selection both directions), dirty-entity-tracked server
//!   aggregation sharded by entity range (`fed::server`, bit-identical
//!   for any shard count), wire protocol (`fed::protocol`), the
//!   composable compression algebra (`fed::compression`: Top-K /
//!   quantize / low-rank stages stacked by `--compress` with per-stage
//!   error feedback, carried as packed delta frames), and the
//!   message-driven orchestrator (`fed::orchestrator`) with its
//!   per-algorithm `Exchange` strategies, sequential/threaded drivers,
//!   and the resolved per-run `RoundParams` its internals consume.
//!   The round loop emits typed events rather than printing or assembling
//!   results inline.  `fed::cluster` deploys the same engine across OS
//!   processes — `feds serve` + N `feds client` — with a versioned
//!   handshake, round deadlines with partial aggregation, dropout
//!   detection, rejoin-with-resync, atomic coordinator checkpoints with
//!   bit-identical crash restore (`--checkpoint` / `--restore`), client
//!   reconnect backoff, seeded participation sampling, and a
//!   fault-injection toolkit (`fed::cluster::chaos`).
//! * [`comm`] — the transport trait hierarchy and accounting:
//!   `comm::transport::Endpoint` is the metered link seam with two
//!   implementations — in-process mpsc duplexes (`transport::mpsc`) and
//!   length-prefixed TCP loopback sockets (`transport::tcp`) — selected
//!   per run by `TransportSpec` (`--transport`), with byte/parameter
//!   accounting bit-identical across transports; plus the wire codec
//!   (`comm::wire`, stream framing included) and bandwidth models.
//! * [`store`] — pluggable embedding storage: the `EmbedStore` trait
//!   (row-addressable f32 tables with shard-range views) with in-RAM
//!   (`VecStore`) and file-backed memory-mapped (`MmapStore`) backends,
//!   selected per run by `StorageSpec` (`--store`).  Zero-initialized
//!   mmap tables are sparse, so resident memory tracks **touched** rows —
//!   the storage seam behind the million-entity scale trajectory
//!   (`benches/scale.rs`) — and backends are bit-identical.
//! * [`data`] — KG generation (streaming — `TripleStream` yields triples
//!   without materializing the graph), federated partitioning (including
//!   the stream-routing `partition_stream`), batch/eval sets.
//! * [`metrics`] — rank metrics, early stopping, run history, and the
//!   observer pipeline (`metrics::observe`): `RunEvent`/`RunObserver`
//!   with the in-memory `HistoryObserver`, console progress, and the
//!   `JsonlSink` metric stream.
//! * [`exp`] — the experiment harness: every paper table/figure is a
//!   declarative sweep grid (`exp::sweep`, base spec × override axes)
//!   executed by one generic runner plus a small report-shaping function.
//! * [`runtime`], [`linalg`], [`util`] — PJRT loader, small dense linear
//!   algebra (incl. the SVD codec's kernel), RNG/JSON/bench/prop-test
//!   support.
//!
//! See DESIGN.md for the full system inventory, PERF.md for hot-path
//! complexity and the `train_hot_path` benchmark, and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod comm;
pub mod data;
pub mod exp;
pub mod fed;
pub mod kge;
pub mod linalg;
pub mod metrics;
pub mod runtime;
pub mod spec;
pub mod store;
pub mod trainer;
pub mod util;

pub use kge::{Hyper, Method};
pub use spec::{ExperimentSpec, Session};

/// Crate version (matches Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
