//! Small dense linear algebra used by the coordinator: rowwise vector ops for
//! the Top-K change scores and a one-sided Jacobi SVD for the FedE-SVD/SVD+
//! compression baselines (Table I).

pub mod svd;

pub use svd::{svd, Svd};

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Cosine similarity, guarded for zero rows (returns 0 like the L1 kernel).
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let den = (dot(a, a) as f64 * dot(b, b) as f64).sqrt();
    if den < 1e-12 {
        return 0.0;
    }
    (dot(a, b) as f64 / den) as f32
}

/// Eq. 1 change score: `1 - cos(cur, hist)` — mirrors the L1 Pallas kernel.
#[inline]
pub fn change_score(cur: &[f32], hist: &[f32]) -> f32 {
    1.0 - cosine(cur, hist)
}

/// `y += alpha * x`
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `y = x`
#[inline]
pub fn copy(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// `a - b` elementwise into a fresh vec.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Scale in place.
#[inline]
pub fn scale(a: &mut [f32], s: f32) {
    for v in a.iter_mut() {
        *v *= s;
    }
}

/// Frobenius norm of the difference of two equal-length buffers.
pub fn frob_diff(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        s += d * d;
    }
    (s as f32).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn change_score_range() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..100 {
            let a: Vec<f32> = (0..8).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..8).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = change_score(&a, &b);
            assert!((0.0..=2.0 + 1e-5).contains(&c), "{c}");
        }
        let a = vec![1.0, 2.0, 3.0];
        assert!(change_score(&a, &a).abs() < 1e-6);
    }

    #[test]
    fn axpy_sub_scale() {
        let x = vec![1.0, 2.0];
        let mut y = vec![10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0]);
        assert_eq!(sub(&y, &x), vec![11.0, 22.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![6.0, 12.0]);
    }
}
