//! One-sided Jacobi SVD for small dense matrices.
//!
//! Used by the FedE-SVD / FedE-SVD+ compression baselines (paper Appendix
//! VI-B): each entity's embedding-update row is reshaped to an (m, n) matrix
//! (m ≥ n, both small — e.g. 8×8 or 16×8) and truncated to rank k before
//! transmission.  One-sided Jacobi is simple, numerically robust, and more
//! than fast enough at these sizes.

/// Thin SVD result: `a = u * diag(s) * vt`, with `u` (m×n), `s` (n),
/// `vt` (n×n), singular values sorted descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub m: usize,
    pub n: usize,
    pub u: Vec<f32>,  // m×n row-major
    pub s: Vec<f32>,  // n
    pub vt: Vec<f32>, // n×n row-major
}

/// Compute the thin SVD of a row-major (m, n) matrix with m ≥ n.
pub fn svd(a: &[f32], m: usize, n: usize) -> Svd {
    assert!(m >= n, "one-sided Jacobi needs m >= n (got {m}x{n})");
    assert_eq!(a.len(), m * n);
    // Work on columns of A (as f64 for stability): one-sided Jacobi
    // orthogonalizes the columns of U' = A·V by plane rotations.
    let mut u: Vec<f64> = a.iter().map(|&x| x as f64).collect(); // m×n
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let col_dot = |u: &[f64], p: usize, q: usize| -> f64 {
        let mut s = 0.0;
        for i in 0..m {
            s += u[i * n + p] * u[i * n + q];
        }
        s
    };

    let eps = 1e-12;
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = col_dot(&u, p, q);
                let app = col_dot(&u, p, p);
                let aqq = col_dot(&u, q, q);
                off += apq * apq;
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[i * n + p];
                    let uq = u[i * n + q];
                    u[i * n + p] = c * up - s * uq;
                    u[i * n + q] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off.sqrt() < 1e-14 {
            break;
        }
    }

    // Column norms are the singular values; normalize U's columns.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigmas = vec![0.0f64; n];
    for (j, sig) in sigmas.iter_mut().enumerate() {
        *sig = col_dot(&u, j, j).sqrt();
    }
    order.sort_by(|&a, &b| sigmas[b].partial_cmp(&sigmas[a]).unwrap());

    let mut u_out = vec![0.0f32; m * n];
    let mut s_out = vec![0.0f32; n];
    let mut vt_out = vec![0.0f32; n * n];
    for (jj, &j) in order.iter().enumerate() {
        let sig = sigmas[j];
        s_out[jj] = sig as f32;
        let inv = if sig > 1e-30 { 1.0 / sig } else { 0.0 };
        for i in 0..m {
            u_out[i * n + jj] = (u[i * n + j] * inv) as f32;
        }
        for i in 0..n {
            vt_out[jj * n + i] = v[i * n + j] as f32; // row jj of V^T = col j of V
        }
    }
    Svd { m, n, u: u_out, s: s_out, vt: vt_out }
}

impl Svd {
    /// Reconstruct with the top-k singular values: `u[:, :k] diag(s[:k]) vt[:k, :]`.
    pub fn reconstruct(&self, k: usize) -> Vec<f32> {
        let k = k.min(self.n);
        let mut out = vec![0.0f32; self.m * self.n];
        for i in 0..self.m {
            for j in 0..self.n {
                let mut acc = 0.0f32;
                for r in 0..k {
                    acc += self.u[i * self.n + r] * self.s[r] * self.vt[r * self.n + j];
                }
                out[i * self.n + j] = acc;
            }
        }
        out
    }

    /// Parameter count of the rank-k factorization as transmitted on the
    /// wire: m·k (U columns) + k (singular values) + k·n (V^T rows) —
    /// exactly the paper's accounting (e.g. 205 = 32·5 + 5 + 8·5 at D=256).
    pub fn transmitted_params(m: usize, n: usize, k: usize) -> usize {
        m * k + k + k * n
    }
}

/// Truncate a row-major (m, n) matrix to rank k (SVD reconstruct shortcut).
pub fn low_rank_project(a: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    if k >= n {
        return a.to_vec();
    }
    svd(a, m, n).reconstruct(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for l in 0..k {
                    acc += a[i * k + l] * b[l * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn identity_svd() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let r = svd(&a, 2, 2);
        assert!((r.s[0] - 1.0).abs() < 1e-5 && (r.s[1] - 1.0).abs() < 1e-5);
        let rec = r.reconstruct(2);
        for (x, y) in rec.iter().zip(&a) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn full_reconstruction_property() {
        check("svd_full_reconstruct", 30, |rng: &mut Rng| {
            let (m, n) = (4 + rng.usize_below(12), 2 + rng.usize_below(6));
            let (m, n) = (m.max(n), n.min(m));
            let a: Vec<f32> = (0..m * n).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let r = svd(&a, m, n);
            let rec = r.reconstruct(n);
            let err = crate::linalg::frob_diff(&a, &rec);
            let scale = crate::linalg::norm(&a).max(1.0);
            assert!(err / scale < 1e-4, "err {err} for {m}x{n}");
        });
    }

    #[test]
    fn singular_values_sorted_and_nonnegative() {
        check("svd_sorted", 30, |rng: &mut Rng| {
            let a: Vec<f32> = (0..48).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let r = svd(&a, 8, 6);
            for w in r.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-6);
            }
            assert!(r.s.iter().all(|&s| s >= 0.0));
        });
    }

    #[test]
    fn u_columns_orthonormal() {
        let mut rng = Rng::new(5);
        let a: Vec<f32> = (0..64).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let r = svd(&a, 8, 8);
        for p in 0..8 {
            for q in 0..8 {
                let mut d = 0.0f32;
                for i in 0..8 {
                    d += r.u[i * 8 + p] * r.u[i * 8 + q];
                }
                let want = if p == q { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-4, "u'u[{p},{q}] = {d}");
            }
        }
    }

    #[test]
    fn rank1_matrix_truncates_exactly() {
        // a = outer(x, y) has rank 1: rank-1 reconstruction must be exact.
        let x = [1.0f32, -2.0, 3.0, 0.5];
        let y = [2.0f32, 1.0, -1.0];
        let mut a = vec![0.0f32; 12];
        for i in 0..4 {
            for j in 0..3 {
                a[i * 3 + j] = x[i] * y[j];
            }
        }
        let r = svd(&a, 4, 3);
        let rec = r.reconstruct(1);
        assert!(crate::linalg::frob_diff(&a, &rec) < 1e-4);
        assert!(r.s[1] < 1e-4 && r.s[2] < 1e-4);
    }

    #[test]
    fn truncation_error_decreases_with_k() {
        let mut rng = Rng::new(9);
        let a: Vec<f32> = (0..128).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let r = svd(&a, 16, 8);
        let mut last = f32::INFINITY;
        for k in 1..=8 {
            let err = crate::linalg::frob_diff(&a, &r.reconstruct(k));
            assert!(err <= last + 1e-5, "k={k} err={err} last={last}");
            last = err;
        }
        assert!(last < 1e-4);
    }

    #[test]
    fn low_rank_project_is_best_approx_vs_random() {
        // Eckart–Young sanity: rank-k SVD projection beats a random rank-k
        // projection (crude but effective invariant).
        let mut rng = Rng::new(11);
        let a: Vec<f32> = (0..64).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let best = low_rank_project(&a, 8, 8, 3);
        let e_best = crate::linalg::frob_diff(&a, &best);
        // random rank-3: B = X(8×3) · Y(3×8)
        let x: Vec<f32> = (0..24).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let y: Vec<f32> = (0..24).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let rnd = matmul(&x, &y, 8, 3, 8);
        let e_rnd = crate::linalg::frob_diff(&a, &rnd);
        assert!(e_best < e_rnd);
    }

    #[test]
    fn transmitted_params_matches_paper() {
        // Paper: D=256 reshaped 32×8, top-5 → 205 params
        assert_eq!(Svd::transmitted_params(32, 8, 5), 205);
        // and 64×8 top-5 → 365 for RotatE/ComplEx
        assert_eq!(Svd::transmitted_params(64, 8, 5), 365);
    }

    #[test]
    fn zero_matrix() {
        let a = vec![0.0f32; 24];
        let r = svd(&a, 6, 4);
        assert!(r.s.iter().all(|&s| s.abs() < 1e-12));
        assert!(r.reconstruct(4).iter().all(|&x| x == 0.0));
    }
}
