//! Heterogeneity study: how FedS's savings scale with federation size.
//!
//! The paper observes (§IV-C) that "the enhancement in communication
//! efficiency of FedS is more pronounced when the dataset comprises more
//! clients".  This example partitions one KG into 3/5/10 clients and
//! compares FedS vs FedEP at each size, also reporting the sharing
//! structure that drives the effect (entities owned by ≥2 clients, mean
//! owners per entity).
//!
//! ```bash
//! cargo run --release --example heterogeneity_study
//! ```

use feds::comm::transport::TransportSpec;
use feds::data::generator::{generate, GeneratorConfig};
use feds::data::partition::partition;
use feds::fed::{run_params, Algo, Backend, ExecMode, RoundParams};
use feds::kge::{Hyper, Method};

fn main() -> anyhow::Result<()> {
    let kg = generate(&GeneratorConfig {
        num_entities: 512,
        num_relations: 30,
        num_triples: 9_000,
        seed: 11,
        ..Default::default()
    });
    let backend = Backend::Native {
        hyper: Hyper { dim: 32, learning_rate: 3e-3, ..Default::default() },
        batch: 128,
        negatives: 32,
        eval_batch: 64,
    };

    println!(
        "{:>8} {:>9} {:>11} {:>10} {:>10} {:>9} {:>9}",
        "clients", "shared", "avg owners", "FedEP MRR", "FedS MRR", "P ratio", "Eq.5"
    );
    for clients in [3usize, 5, 10] {
        let data = partition(&kg, clients, 11);
        let avg_owners: f64 = data.owners.iter().map(|o| o.len() as f64).sum::<f64>()
            / data.num_entities as f64;

        let run = |algo: Algo| {
            let cfg = RoundParams {
                algo,
                method: Method::TransE,
                max_rounds: 30,
                local_epochs: 3,
                eval_every: 5,
                patience: 3,
                sparsity: 0.4,
                sync_interval: 4,
                eval_cap: 192,
                seed: 5,
                svd_cols: 8,
                exec: ExecMode::Sequential,
                transport: TransportSpec::Mpsc,
                shards: 1,
                participation: Default::default(),
                storage: Default::default(),
                compression: Default::default(),
            };
            run_params(&data, &cfg, &backend, &mut [])
        };
        let fedep = run(Algo::FedEP)?;
        let feds = run(Algo::FedS { sync: true })?;
        let ratio = feds.history.params_cg() as f64 / fedep.history.params_cg().max(1) as f64;
        println!(
            "{:>8} {:>9} {:>11.2} {:>10.4} {:>10.4} {:>8.3}x {:>8.3}x",
            clients,
            data.shared.len(),
            avg_owners,
            fedep.history.mrr_cg(),
            feds.history.mrr_cg(),
            ratio,
            feds.eq5_ratio.unwrap()
        );
    }
    println!("\n(expect: more clients → wider sharing → FedS's ratio further below the Eq.5 bound,");
    println!(" because under-supplied downstream Top-K sends fewer than K entities — §III-F's note)");
    Ok(())
}
