//! Quickstart: 60 seconds to FedS, on the declarative experiment API.
//!
//! Describes two runs as [`ExperimentSpec`]s (the dense FedEP baseline and
//! FedS Entity-Wise Top-K sparsification), executes them through one
//! [`Session`], watches progress with a custom [`RunObserver`], and prints
//! accuracy + communication savings.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! No artifacts needed — for the production AOT/PJRT path see
//! `examples/e2e_federated_training.rs`.

use feds::fed::ExecMode;
use feds::kge::Method;
use feds::metrics::observe::{RunEvent, RunObserver};
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, Session};

/// Observers receive typed events from the round loop — no stdout
/// scraping.  This one prints a one-line progress ticker per evaluation.
struct Ticker;

impl RunObserver for Ticker {
    fn on_event(&mut self, ev: &RunEvent) {
        if let RunEvent::Evaluated { record } = ev {
            println!(
                "  round {:>3}: loss {:.4} valid MRR {:.4} ({} params so far)",
                record.round, record.mean_loss, record.valid.mrr, record.params_cum
            );
        }
    }
}

fn main() -> anyhow::Result<()> {
    // 1. one declarative description of the experiment: data, backend,
    //    budget, and the algorithm with only its own knobs
    let mut spec = ExperimentSpec {
        name: "quickstart".into(),
        method: Method::TransE,
        algo: AlgoSpec::FedEP,
        data: DataSpec {
            entities: 512,
            relations: 24,
            triples: 8_000,
            clusters: 8,
            clients: 3,
            seed: 42,
        },
        backend: BackendSpec::Native {
            dim: 32,
            learning_rate: 3e-3,
            batch: 128,
            negatives: 32,
            eval_batch: 64,
        },
        budget: BudgetSpec {
            max_rounds: 40,
            local_epochs: 3,
            eval_every: 5,
            patience: 3,
            eval_cap: 256,
        },
        seed: 7,
        exec: ExecMode::Sequential,
        transport: Default::default(),
        shards: 0,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    };
    // every spec is JSON-serializable: println!("{}", spec.to_json()) is a
    // ready-made `feds run --spec` file

    // 2. a session builds runs (and caches the PJRT runtime when used)
    let mut session = Session::new();
    let mut results = Vec::new();
    for algo in [AlgoSpec::FedEP, AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: true }] {
        spec.algo = algo;
        let mut run = session.build(&spec)?;
        if results.is_empty() {
            let data = run.data();
            println!(
                "federated KG: {} entities ({} shared), {} relations, {} triples, {} clients\n",
                data.num_entities,
                data.shared.len(),
                data.num_relations,
                data.total_triples(),
                data.clients.len()
            );
        }
        println!("{} …", run.spec().algo.label());
        run.quiet().observe(Box::new(Ticker));
        let out = run.execute()?;
        println!(
            "{:<8} converged @ round {:>3}: MRR {:.4}  Hits@10 {:.4}  transmitted {:>11} params\n",
            out.history.label,
            out.history.rounds_cg(),
            out.history.mrr_cg(),
            out.history.hits10_cg(),
            out.history.params_cg(),
        );
        results.push(out);
    }

    // 3. the headline: accuracy parity at a fraction of the traffic
    let (fedep, feds) = (&results[0], &results[1]);
    println!(
        "FedS transmitted {:.1}% of FedEP's parameters at convergence \
         (Eq.5 worst-case bound: {:.1}%)",
        100.0 * feds.history.params_cg() as f64 / fedep.history.params_cg() as f64,
        100.0 * feds.eq5_ratio.unwrap()
    );
    Ok(())
}
