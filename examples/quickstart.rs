//! Quickstart: 60 seconds to FedS.
//!
//! Generates a small federated KG (3 clients, relation-partitioned), trains
//! FedEP (dense baseline) and FedS (Entity-Wise Top-K sparsification) on
//! the pure-Rust backend, and prints accuracy + communication savings.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! No artifacts needed — for the production AOT/PJRT path see
//! `examples/e2e_federated_training.rs`.

use feds::data::generator::{generate, GeneratorConfig};
use feds::data::partition::partition;
use feds::fed::{run_federated, Algo, Backend, FedRunConfig};
use feds::kge::{Hyper, Method};

fn main() -> anyhow::Result<()> {
    // 1. a synthetic FB15k-237-like KG, split into 3 clients by relation
    let kg = generate(&GeneratorConfig {
        num_entities: 512,
        num_relations: 24,
        num_triples: 8_000,
        seed: 42,
        ..Default::default()
    });
    let data = partition(&kg, 3, 42);
    println!(
        "federated KG: {} entities ({} shared), {} relations, {} triples, {} clients\n",
        data.num_entities,
        data.shared.len(),
        data.num_relations,
        data.total_triples(),
        data.clients.len()
    );

    // 2. a local-training backend (pure Rust here; Backend::Xla for PJRT)
    let backend = Backend::Native {
        hyper: Hyper { dim: 32, learning_rate: 3e-3, ..Default::default() },
        batch: 128,
        negatives: 32,
        eval_batch: 64,
    };

    // 3. run the dense baseline and FedS
    let mut results = Vec::new();
    for algo in [Algo::FedEP, Algo::FedS { sync: true }] {
        let cfg = FedRunConfig {
            algo,
            method: Method::TransE,
            max_rounds: 40,
            eval_every: 5,
            eval_cap: 256,
            seed: 7,
            ..Default::default()
        };
        let out = run_federated(&data, &cfg, &backend)?;
        println!(
            "{:<8} converged @ round {:>3}: MRR {:.4}  Hits@10 {:.4}  transmitted {:>11} params",
            out.history.label,
            out.history.rounds_cg(),
            out.history.mrr_cg(),
            out.history.hits10_cg(),
            out.history.params_cg(),
        );
        results.push(out);
    }

    // 4. the headline: accuracy parity at a fraction of the traffic
    let (fedep, feds) = (&results[0], &results[1]);
    println!(
        "\nFedS transmitted {:.1}% of FedEP's parameters at convergence \
         (Eq.5 worst-case bound: {:.1}%)",
        100.0 * feds.history.params_cg() as f64 / fedep.history.params_cg() as f64,
        100.0 * feds.eq5_ratio.unwrap()
    );
    Ok(())
}
