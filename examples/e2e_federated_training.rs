//! End-to-end validation driver (the EXPERIMENTS.md §E2E run), on the
//! declarative experiment API.
//!
//! Exercises the full production stack on a real small workload:
//!
//!   Pallas scoring kernels (L1) → JAX train/eval graphs (L2) → AOT HLO
//!   text → PJRT CPU runtime → Rust federated coordinator (L3)
//!
//! Trains both FedEP and FedS with TransE on the R3 analogue of the
//! synthetic FB15k-237 benchmark (2048 entities, ~31k triples, ~1.6M model
//! parameters per client) via `Session`-built specs, streams every run
//! event to a JSONL sink under `reports/`, and reports the communication
//! savings + simulated wall-clock on an edge link.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_federated_training
//! ```

use std::fmt::Write as _;

use feds::comm::BandwidthModel;
use feds::exp;
use feds::fed::ExecMode;
use feds::kge::Method;
use feds::metrics::observe::JsonlSink;
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, Session};

fn main() -> anyhow::Result<()> {
    // shape the data spec from the artifact manifest, then hand the loaded
    // runtime to the session so every build reuses it
    let rt = exp::xla_runtime()?;
    let mut spec = ExperimentSpec {
        name: "e2e".into(),
        method: Method::TransE,
        algo: AlgoSpec::FedEP,
        data: DataSpec {
            entities: rt.manifest.num_entities,
            relations: rt.manifest.num_relations,
            triples: rt.manifest.num_entities * 15,
            clusters: 8,
            clients: 3,
            seed: 64501,
        },
        backend: BackendSpec::Xla,
        budget: BudgetSpec {
            max_rounds: 40,
            local_epochs: 3,
            eval_every: 5,
            patience: 3,
            eval_cap: 384,
        },
        seed: 64501,
        exec: ExecMode::Sequential,
        transport: Default::default(),
        shards: 0,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    };
    let mut session = Session::with_runtime(rt);

    std::fs::create_dir_all(exp::reports_dir())?;
    let jsonl_path = exp::reports_dir().join("e2e_events.jsonl");
    // one JSONL stream shared by both runs: run_start lines delimit them
    let mut sink = JsonlSink::create(&jsonl_path)?;

    let mut md = String::from("# E2E run: FedEP vs FedS (TransE, R3 analogue, XLA backend)\n\n");
    let mut outcomes = Vec::new();
    for algo in [AlgoSpec::FedEP, AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: true }] {
        spec.algo = algo;
        let mut run = session.build(&spec)?;
        if outcomes.is_empty() {
            let data = run.data();
            println!(
                "== e2e driver: {} entities / {} relations / {} triples, 3 clients ==\n",
                data.num_entities,
                data.num_relations,
                data.total_triples()
            );
        }
        let t0 = std::time::Instant::now();
        let out = run.execute_with(&mut [&mut sink])?;
        let secs = t0.elapsed().as_secs_f64();

        println!("--- {} ({secs:.1}s wall) ---", out.history.label);
        println!("{:>6} {:>10} {:>10} {:>12} {:>12}", "round", "loss", "testMRR", "params", "MBytes");
        writeln!(md, "## {}\n", out.history.label)?;
        writeln!(md, "| round | loss | valid MRR | test MRR | params (cum) | bytes (cum) |")?;
        writeln!(md, "|---|---|---|---|---|---|")?;
        for r in &out.history.records {
            println!(
                "{:>6} {:>10.4} {:>10.4} {:>12} {:>12.2}",
                r.round,
                r.mean_loss,
                r.test.mrr,
                r.params_cum,
                r.bytes_cum as f64 / 1e6
            );
            writeln!(
                md,
                "| {} | {:.4} | {:.4} | {:.4} | {} | {} |",
                r.round, r.mean_loss, r.valid.mrr, r.test.mrr, r.params_cum, r.bytes_cum
            )?;
        }
        println!(
            "converged @ round {}: MRR {:.4} Hits@10 {:.4}\n",
            out.history.rounds_cg(),
            out.history.mrr_cg(),
            out.history.hits10_cg()
        );
        writeln!(
            md,
            "\nconverged @ round {}: **MRR {:.4}**, Hits@10 {:.4}, {} params, {} bytes\n",
            out.history.rounds_cg(),
            out.history.mrr_cg(),
            out.history.hits10_cg(),
            out.history.params_cg(),
            out.history.converged().bytes_cum,
        )?;
        outcomes.push(out);
    }

    let (fedep, feds) = (&outcomes[0], &outcomes[1]);
    let ratio =
        feds.history.params_cg() as f64 / fedep.history.params_cg().max(1) as f64;
    let edge = BandwidthModel::edge();
    let t_fedep = edge.time_for(fedep.history.converged().bytes_cum, 1);
    let t_feds = edge.time_for(feds.history.converged().bytes_cum, 1);
    println!("== summary ==");
    println!("FedS / FedEP params at convergence : {:.4}x", ratio);
    println!("Eq.5 worst-case bound              : {:.4}x", feds.eq5_ratio.unwrap());
    println!(
        "simulated 10 Mbit/s edge link       : FedEP {t_fedep:.1}s vs FedS {t_feds:.1}s of pure transfer"
    );
    println!(
        "MRR delta (FedS − FedEP)            : {:+.4}",
        feds.history.mrr_cg() - fedep.history.mrr_cg()
    );
    writeln!(
        md,
        "## Summary\n\n- params ratio FedS/FedEP at CG: **{ratio:.4}x** (Eq.5 bound {:.4}x)\n\
         - MRR delta: {:+.4}\n- 10 Mbit/s edge transfer time: FedEP {t_fedep:.1}s vs FedS {t_feds:.1}s\n",
        feds.eq5_ratio.unwrap(),
        feds.history.mrr_cg() - fedep.history.mrr_cg()
    )?;

    let path = exp::reports_dir().join("e2e_run.md");
    std::fs::write(&path, md)?;
    println!("\n(report saved to {}; events streamed to {})", path.display(), jsonl_path.display());
    Ok(())
}
