//! Communication-budget study: time-to-accuracy on constrained links.
//!
//! The paper's motivation (§I) is bandwidth-constrained edge deployments.
//! This example converts the byte-exact wire accounting of FedEP vs FedS
//! runs into wall-clock transfer time under the `comm::bandwidth` link
//! models (10 Mbit/s edge vs 1 Gbit/s datacenter), and prints
//! accuracy-vs-transfer-seconds tables — the deployment-facing view of
//! Table III.
//!
//! ```bash
//! cargo run --release --example communication_budget
//! ```

use feds::comm::transport::TransportSpec;
use feds::comm::BandwidthModel;
use feds::data::generator::{generate, GeneratorConfig};
use feds::data::partition::partition;
use feds::fed::{run_params, Algo, Backend, ExecMode, RoundParams, RunOutcome};
use feds::kge::{Hyper, Method};

fn main() -> anyhow::Result<()> {
    let kg = generate(&GeneratorConfig {
        num_entities: 512,
        num_relations: 24,
        num_triples: 8_000,
        seed: 23,
        ..Default::default()
    });
    let data = partition(&kg, 5, 23);
    let backend = Backend::Native {
        hyper: Hyper { dim: 32, learning_rate: 3e-3, ..Default::default() },
        batch: 128,
        negatives: 32,
        eval_batch: 64,
    };

    let run = |algo: Algo| -> anyhow::Result<RunOutcome> {
        let cfg = RoundParams {
            algo,
            method: Method::TransE,
            max_rounds: 40,
            local_epochs: 3,
            eval_every: 5,
            patience: 3,
            sparsity: 0.4,
            sync_interval: 4,
            eval_cap: 256,
            seed: 3,
            svd_cols: 8,
            exec: ExecMode::Sequential,
            transport: TransportSpec::Mpsc,
            shards: 1,
            participation: Default::default(),
            storage: Default::default(),
            compression: Default::default(),
        };
        run_params(&data, &cfg, &backend, &mut [])
    };
    let fedep = run(Algo::FedEP)?;
    let feds = run(Algo::FedS { sync: true })?;

    for (lname, link) in [
        ("edge 10 Mbit/s + 20 ms", BandwidthModel::edge()),
        ("datacenter 1 Gbit/s + 1 ms", BandwidthModel::datacenter()),
    ] {
        println!("== link: {lname} ==");
        println!(
            "{:>8} | {:>10} {:>12} | {:>10} {:>12}",
            "", "FedEP MRR", "transfer s", "FedS MRR", "transfer s"
        );
        let rows = fedep.history.records.len().max(feds.history.records.len());
        for i in 0..rows {
            let cell = |o: &RunOutcome| {
                o.history.records.get(i).map(|r| {
                    let msgs = o.acct.messages() / o.history.records.len().max(1) as u64;
                    (r.round, r.test.mrr, link.time_for(r.bytes_cum, msgs * i as u64))
                })
            };
            let a = cell(&fedep);
            let b = cell(&feds);
            let round = a.map(|x| x.0).or(b.map(|x| x.0)).unwrap_or(0);
            println!(
                "round {round:>3} | {:>10} {:>12} | {:>10} {:>12}",
                a.map(|x| format!("{:.4}", x.1)).unwrap_or_else(|| "-".into()),
                a.map(|x| format!("{:.1}", x.2)).unwrap_or_else(|| "-".into()),
                b.map(|x| format!("{:.4}", x.1)).unwrap_or_else(|| "-".into()),
                b.map(|x| format!("{:.1}", x.2)).unwrap_or_else(|| "-".into()),
            );
        }
        let speedup = link.time_for(fedep.history.converged().bytes_cum, 1)
            / link.time_for(feds.history.converged().bytes_cum, 1).max(1e-9);
        println!(
            "at convergence: FedS needs {speedup:.2}x less transfer time for MRR {:.4} (FedEP {:.4})\n",
            feds.history.mrr_cg(),
            fedep.history.mrr_cg()
        );
    }
    Ok(())
}
